//! Dense bit-packing of quantization codes.
//!
//! Quantizers emit one code in `0..2^s` per kept gradient element
//! (`s` ∈ 1..=16). On the wire each code occupies exactly `s` bits,
//! LSB-first within a little-endian bit stream — the format DEFLATE then
//! compresses further.
//!
//! Implementation: a 64-bit accumulator flushing whole little-endian
//! words, instead of the byte-at-a-time loop of earlier revisions. The
//! wire layout is unchanged byte-for-byte (`tests/wire_format.rs` pins
//! it): an LSB-first bit stream has exactly one byte serialization, so
//! any flush granularity produces identical output — words just cut the
//! bookkeeping per code from ~`s/8` byte stores to ~`s/64` word stores.

/// Pack `codes` (each `< 2^bits`) into a byte vector, LSB-first.
pub fn pack(codes: &[u16], bits: u8) -> Vec<u8> {
    let mut out = Vec::new();
    pack_into(codes, bits, &mut out);
    out
}

/// [`pack`] into a reusable buffer (cleared first).
pub fn pack_into(codes: &[u16], bits: u8, out: &mut Vec<u8>) {
    assert!((1..=16).contains(&bits), "bits must be in 1..=16");
    let bits = bits as u32;
    let total_bits = codes.len() * bits as usize;
    out.clear();
    out.resize(total_bits.div_ceil(8), 0);
    let mut acc: u64 = 0; // bit accumulator
    let mut nbits: u32 = 0; // valid bits in acc
    let mut pos = 0usize; // next output byte
    for &c in codes {
        debug_assert!(
            (c as u32) < (1u32 << bits),
            "code {c} does not fit in {bits} bits"
        );
        // The shift drops any bits beyond 64; they are exactly the high
        // bits of `c` re-seeded into the fresh accumulator after a flush.
        acc |= (c as u64) << nbits;
        nbits += bits;
        if nbits >= 64 {
            out[pos..pos + 8].copy_from_slice(&acc.to_le_bytes());
            pos += 8;
            nbits -= 64;
            acc = if nbits > 0 { (c as u64) >> (bits - nbits) } else { 0 };
        }
    }
    if nbits > 0 {
        let tail = acc.to_le_bytes();
        let nb = (nbits as usize).div_ceil(8);
        out[pos..pos + nb].copy_from_slice(&tail[..nb]);
    }
}

/// Unpack `n` codes of `bits` bits each from `bytes`.
pub fn unpack(bytes: &[u8], bits: u8, n: usize) -> Vec<u16> {
    let mut out = Vec::new();
    unpack_into(bytes, bits, n, &mut out);
    out
}

/// Guarded little-endian 64-bit load. Callers bound-check `pos + 8 <=
/// bytes.len()` before refilling; the `get`-based load keeps the word
/// refill panic-free by construction (out-of-range reads as 0) instead of
/// relying on a `try_into().unwrap()` the decode path cannot afford.
#[inline]
fn le_word(bytes: &[u8], pos: usize) -> u64 {
    bytes
        .get(pos..pos + 8)
        .and_then(|s| <[u8; 8]>::try_from(s).ok())
        .map_or(0, u64::from_le_bytes)
}

/// [`unpack`] into a reusable buffer (cleared first).
pub fn unpack_into(bytes: &[u8], bits: u8, n: usize, out: &mut Vec<u16>) {
    assert!((1..=16).contains(&bits), "bits must be in 1..=16");
    let bits = bits as u32;
    let needed = (n * bits as usize).div_ceil(8);
    assert!(
        bytes.len() >= needed,
        "unpack: need {needed} bytes for {n} codes of {bits} bits, got {}",
        bytes.len()
    );
    let mask: u64 = (1u64 << bits) - 1;
    out.clear();
    out.reserve(n);
    // 128-bit accumulator: refills pull a whole 64-bit word while up to 63
    // residual bits are still pending, so the hot loop touches memory once
    // per 64 bits instead of once per byte.
    let mut acc: u128 = 0;
    let mut nbits: u32 = 0;
    let mut pos = 0usize;
    for _ in 0..n {
        if nbits < bits {
            if pos + 8 <= bytes.len() {
                acc |= (le_word(bytes, pos) as u128) << nbits;
                pos += 8;
                nbits += 64;
            } else {
                while nbits < bits {
                    acc |= (bytes[pos] as u128) << nbits;
                    pos += 1;
                    nbits += 8;
                }
            }
        }
        out.push((acc as u64 & mask) as u16);
        acc >>= bits;
        nbits -= bits;
    }
}

/// Unpack codes `start..start + count` of an LSB-first stream without
/// touching the preceding codes: seek to the byte containing bit
/// `start·bits`, discard the sub-byte remainder once, then run the same
/// word-refill loop as [`unpack_into`]. An LSB-first stream is a pure
/// function of bit position, so the output is **bit-identical** to
/// `unpack_into(..)` followed by slicing `[start..start + count]` — the
/// contract the sharded ingest plane's per-shard sub-range folds rely on
/// (pinned in `tests/kernel_equivalence.rs`).
pub fn unpack_range_into(bytes: &[u8], bits: u8, start: usize, count: usize, out: &mut Vec<u16>) {
    assert!((1..=16).contains(&bits), "bits must be in 1..=16");
    out.clear();
    if count == 0 {
        return;
    }
    let bits = bits as u32;
    let needed = ((start + count) * bits as usize).div_ceil(8);
    assert!(
        bytes.len() >= needed,
        "unpack_range: need {needed} bytes for codes ..{} of {bits} bits, got {}",
        start + count,
        bytes.len()
    );
    out.reserve(count);
    let mask: u64 = (1u64 << bits) - 1;
    let first_bit = start * bits as usize;
    let mut pos = first_bit / 8;
    // Bits of the first loaded byte that belong to code `start - 1`;
    // shifted out exactly once, right after the first refill.
    let mut discard = (first_bit % 8) as u32;
    let mut acc: u128 = 0;
    let mut nbits: u32 = 0;
    for _ in 0..count {
        if nbits < bits {
            while nbits < bits + discard {
                if pos + 8 <= bytes.len() {
                    acc |= (le_word(bytes, pos) as u128) << nbits;
                    pos += 8;
                    nbits += 64;
                } else {
                    acc |= (bytes[pos] as u128) << nbits;
                    pos += 1;
                    nbits += 8;
                }
            }
            if discard > 0 {
                acc >>= discard;
                nbits -= discard;
                discard = 0;
            }
        }
        out.push((acc as u64 & mask) as u16);
        acc >>= bits;
        nbits -= bits;
    }
}

/// Number of payload bytes for `n` codes at `bits` bits each.
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Pcg64::seeded(21);
        for bits in 1..=16u8 {
            let n = 1 + rng.below_usize(500);
            let max = 1u32 << bits;
            let codes: Vec<u16> = (0..n).map(|_| rng.below(max as u64) as u16).collect();
            let packed = pack(&codes, bits);
            assert_eq!(packed.len(), packed_len(n, bits));
            assert_eq!(unpack(&packed, bits, n), codes, "bits={bits} n={n}");
        }
    }

    #[test]
    fn two_bit_layout_is_lsb_first() {
        // codes [1,2,3,0] at 2 bits -> byte 0b00_11_10_01 = 0x39
        assert_eq!(pack(&[1, 2, 3, 0], 2), vec![0x39]);
        assert_eq!(unpack(&[0x39], 2, 4), vec![1, 2, 3, 0]);
    }

    #[test]
    fn one_bit_layout() {
        // codes [1,0,0,0, 0,0,0,1, 1] -> bytes [0b1000_0001, 0b0000_0001]
        assert_eq!(pack(&[1, 0, 0, 0, 0, 0, 0, 1, 1], 1), vec![0x81, 0x01]);
    }

    #[test]
    fn empty_input() {
        assert!(pack(&[], 4).is_empty());
        assert!(unpack(&[], 4, 0).is_empty());
    }

    #[test]
    fn property_roundtrip() {
        forall(
            100,
            22,
            |rng, size| {
                let bits = 1 + rng.below(16) as u8;
                let n = size.len(rng) * 4;
                let codes: Vec<u16> =
                    (0..n).map(|_| rng.below(1u64 << bits) as u16).collect();
                (bits, codes)
            },
            |(bits, codes)| unpack(&pack(codes, *bits), *bits, codes.len()) == *codes,
        );
    }

    #[test]
    #[should_panic(expected = "bits must be in 1..=16")]
    fn rejects_zero_bits() {
        pack(&[0], 0);
    }

    #[test]
    fn range_unpack_matches_full_unpack_slices() {
        let mut rng = Pcg64::seeded(23);
        let mut ranged = Vec::new();
        for bits in 1..=16u8 {
            let n = 64 + rng.below_usize(500);
            let codes: Vec<u16> =
                (0..n).map(|_| rng.below(1u64 << bits) as u16).collect();
            let packed = pack(&codes, bits);
            let full = unpack(&packed, bits, n);
            // Aligned, unaligned, head, tail, singleton and empty ranges.
            let starts = [0usize, 1, 7, 8, n / 3, n - 1, n];
            for &start in &starts {
                for count in [0usize, 1, 5, n - start] {
                    if start + count > n {
                        continue;
                    }
                    unpack_range_into(&packed, bits, start, count, &mut ranged);
                    assert_eq!(
                        ranged,
                        &full[start..start + count],
                        "bits={bits} n={n} start={start} count={count}"
                    );
                }
            }
        }
    }

    #[test]
    fn range_unpack_whole_range_is_unpack() {
        let mut rng = Pcg64::seeded(24);
        for bits in [1u8, 3, 5, 8, 11, 16] {
            let n = 1 + rng.below_usize(300);
            let codes: Vec<u16> =
                (0..n).map(|_| rng.below(1u64 << bits) as u16).collect();
            let packed = pack(&codes, bits);
            let mut out = Vec::new();
            unpack_range_into(&packed, bits, 0, n, &mut out);
            assert_eq!(out, codes, "bits={bits} n={n}");
        }
    }
}
