//! Codec composition — the full client→server compression pipeline
//! (Algorithm 1): sparsify → (rotate) → quantize → bit-pack → DEFLATE.
//!
//! One [`Codec`] value describes a complete scheme; [`Codec::encode`] turns
//! a dense gradient into an [`EncodedGradient`] (what travels on the wire)
//! and [`Codec::decode`] inverts it on the server into a dense vector.
//! Per-client state (EF-signSGD's residual memory) lives in
//! [`ClientCodecState`], never on the wire.

use crate::util::rng::Pcg64;

use super::bitpack;
use super::cosine::{BoundMode, CosineQuantizer, Rounding};
use super::deflate::{self, CompressionLevel};
use super::hadamard;
use super::linear::{LinearQuantizer, ValueBound};
use super::signsgd::{self, ErrorFeedback};
use super::sparsify;

/// Which compression family to apply to the (possibly sparsified) values.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CodecKind {
    /// No quantization: raw float32 payload (the paper's baseline).
    Float32,
    /// CosSGD (the paper's contribution).
    Cosine {
        bits: u8,
        rounding: Rounding,
        bound: BoundMode,
    },
    /// Value-space linear quantization ("linear" / "linear (U)").
    Linear { bits: u8, rounding: Rounding },
    /// Linear after a randomized Hadamard rotation ("linear (U, R)").
    LinearRotated { bits: u8, rounding: Rounding },
    /// signSGD [4]: signs only, unit magnitude.
    SignSgd,
    /// signSGD+Norm [43] — identical to 1-bit CosSGD.
    SignSgdNorm,
    /// EF-signSGD [15] — signs with client-local error feedback.
    EfSignSgd,
}

impl CodecKind {
    /// Stable wire id.
    pub fn id(&self) -> u8 {
        match self {
            CodecKind::Float32 => 0,
            CodecKind::Cosine { .. } => 1,
            CodecKind::Linear { .. } => 2,
            CodecKind::LinearRotated { .. } => 3,
            CodecKind::SignSgd => 4,
            CodecKind::SignSgdNorm => 5,
            CodecKind::EfSignSgd => 6,
        }
    }

    /// Bits per transmitted code (4×8 for float32).
    pub fn bits(&self) -> u8 {
        match *self {
            CodecKind::Float32 => 32,
            CodecKind::Cosine { bits, .. }
            | CodecKind::Linear { bits, .. }
            | CodecKind::LinearRotated { bits, .. } => bits,
            CodecKind::SignSgd | CodecKind::SignSgdNorm | CodecKind::EfSignSgd => 1,
        }
    }

    /// Short human name (figures / CLI).
    pub fn name(&self) -> String {
        match *self {
            CodecKind::Float32 => "float32".into(),
            CodecKind::Cosine { bits, rounding, .. } => format!(
                "cosine-{bits}{}",
                if rounding == Rounding::Unbiased { " (U)" } else { "" }
            ),
            CodecKind::Linear { bits, rounding } => format!(
                "linear-{bits}{}",
                if rounding == Rounding::Unbiased { " (U)" } else { "" }
            ),
            CodecKind::LinearRotated { bits, .. } => format!("linear-{bits} (U,R)"),
            CodecKind::SignSgd => "signSGD".into(),
            CodecKind::SignSgdNorm => "signSGD+Norm".into(),
            CodecKind::EfSignSgd => "EF-signSGD".into(),
        }
    }
}

/// A complete compression scheme.
#[derive(Debug, Clone, Copy)]
pub struct Codec {
    pub kind: CodecKind,
    /// Fraction of coordinates transmitted (random mask [17]); 1.0 = all.
    pub keep_frac: f64,
    /// Apply DEFLATE to the packed payload (§4).
    pub deflate: bool,
    pub level: CompressionLevel,
}

impl Codec {
    pub fn new(kind: CodecKind) -> Self {
        Codec {
            kind,
            keep_frac: 1.0,
            deflate: true,
            level: CompressionLevel::Default,
        }
    }

    /// The paper's default CosSGD config at `bits` (biased, top-1% clip).
    pub fn cosine(bits: u8) -> Self {
        Codec::new(CodecKind::Cosine {
            bits,
            rounding: Rounding::Biased,
            bound: BoundMode::ClipTopPercent(1.0),
        })
    }

    /// Uncompressed float32 baseline (no DEFLATE — matching the paper's
    /// float32 cost accounting; Fig. 5 shows it would gain only ~1.07×).
    pub fn float32() -> Self {
        Codec {
            kind: CodecKind::Float32,
            keep_frac: 1.0,
            deflate: false,
            level: CompressionLevel::Default,
        }
    }

    pub fn with_sparsify(mut self, keep_frac: f64) -> Self {
        assert!((0.0..=1.0).contains(&keep_frac));
        self.keep_frac = keep_frac;
        self
    }

    pub fn without_deflate(mut self) -> Self {
        self.deflate = false;
        self
    }

    pub fn name(&self) -> String {
        let mut s = self.kind.name();
        if self.keep_frac < 1.0 {
            s.push_str(&format!(" @{}%", (self.keep_frac * 100.0).round()));
        }
        s
    }

    /// Encode a dense gradient. `rng` drives stochastic rounding and the
    /// mask/rotation seeds; `state` carries EF memory across rounds.
    pub fn encode(
        &self,
        g: &[f32],
        state: &mut ClientCodecState,
        rng: &mut Pcg64,
    ) -> EncodedGradient {
        let n = g.len();
        // --- sparsify ------------------------------------------------------
        let (mask_seed, kept_values, kept_n) = if self.keep_frac < 1.0 {
            let seed = rng.next_u64();
            let m = sparsify::mask(seed, n, self.keep_frac);
            let mut vals = sparsify::gather(g, &m);
            // EF-signSGD folds unsent coordinates into the residual below;
            // other codecs simply drop them (paper §4).
            if self.kind == CodecKind::EfSignSgd {
                vals = ef_pre_mask(g, &m, state);
            }
            let k = vals.len();
            (seed, vals, k)
        } else {
            (0u64, g.to_vec(), n)
        };

        // --- quantize ------------------------------------------------------
        let (codes, bits, norm, bound, rot_seed) = match self.kind {
            CodecKind::Float32 => {
                let payload_raw = crate::compress::entropy::f32_bytes(&kept_values);
                let (payload, deflated) = self.finish_payload(payload_raw);
                return EncodedGradient {
                    kind_id: self.kind.id(),
                    bits: 32,
                    n: n as u32,
                    kept: kept_n as u32,
                    mask_seed,
                    rot_seed: 0,
                    norm: 0.0,
                    bound: 0.0,
                    deflated,
                    payload,
                };
            }
            CodecKind::Cosine {
                bits,
                rounding,
                bound,
            } => {
                let q = CosineQuantizer::new(bits, rounding, bound)
                    .quantize(&kept_values, rng);
                (q.codes, bits, q.norm, q.bound, 0u64)
            }
            CodecKind::Linear { bits, rounding } => {
                let q = LinearQuantizer::new(bits, rounding, ValueBound::MaxAbs)
                    .quantize(&kept_values, rng);
                (q.codes, bits, 0.0, q.bound, 0u64)
            }
            CodecKind::LinearRotated { bits, rounding } => {
                let rot_seed = rng.next_u64();
                let rotated = hadamard::rotate(&kept_values, rot_seed);
                let q = LinearQuantizer::new(bits, rounding, ValueBound::MaxAbs)
                    .quantize(&rotated, rng);
                (q.codes, bits, 0.0, q.bound, rot_seed)
            }
            CodecKind::SignSgd => {
                (signsgd::sign_codes(&kept_values), 1, 0.0, 0.0, 0u64)
            }
            CodecKind::SignSgdNorm => {
                let norm = signsgd::norm2(&kept_values);
                (signsgd::sign_codes(&kept_values), 1, norm, 0.0, 0u64)
            }
            CodecKind::EfSignSgd => {
                if self.keep_frac >= 1.0 {
                    let (codes, scale) = state.ef.encode(&kept_values);
                    (codes, 1, 0.0, scale, 0u64)
                } else {
                    // kept_values already went through the EF residual in
                    // ef_pre_mask; codes are their signs and the scale was
                    // stashed in the state.
                    let codes = signsgd::sign_codes(&kept_values);
                    (codes, 1, 0.0, state.last_scale, 0u64)
                }
            }
        };

        let packed = bitpack::pack(&codes, bits);
        let (payload, deflated) = self.finish_payload(packed);
        EncodedGradient {
            kind_id: self.kind.id(),
            bits,
            n: n as u32,
            kept: kept_n as u32,
            mask_seed,
            rot_seed,
            norm,
            bound,
            deflated,
            payload,
        }
    }

    fn finish_payload(&self, raw: Vec<u8>) -> (Vec<u8>, bool) {
        if self.deflate {
            let c = deflate::deflate(&raw, self.level);
            if c.len() < raw.len() {
                return (c, true);
            }
        }
        (raw, false)
    }

    /// Decode an update back to a dense gradient of length `enc.n`.
    pub fn decode(&self, enc: &EncodedGradient) -> crate::Result<Vec<f32>> {
        let raw = if enc.deflated {
            deflate::inflate(&enc.payload)?
        } else {
            enc.payload.clone()
        };
        let kept = enc.kept as usize;
        let n = enc.n as usize;

        let values: Vec<f32> = match self.kind {
            CodecKind::Float32 => {
                anyhow::ensure!(raw.len() == kept * 4, "float32 payload size");
                raw.chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect()
            }
            CodecKind::Cosine { bits, .. } => {
                let codes = bitpack::unpack(&raw, bits, kept);
                super::cosine::dequantize_codes(&codes, enc.norm, enc.bound, bits)
            }
            CodecKind::Linear { bits, .. } => {
                let codes = bitpack::unpack(&raw, bits, kept);
                super::linear::dequantize_codes(&codes, enc.bound, bits)
            }
            CodecKind::LinearRotated { bits, .. } => {
                let padded = hadamard::padded_len(kept.max(1));
                let codes = bitpack::unpack(&raw, bits, padded);
                let rotated = super::linear::dequantize_codes(&codes, enc.bound, bits);
                hadamard::unrotate(&rotated, enc.rot_seed, kept)
            }
            CodecKind::SignSgd => {
                let codes = bitpack::unpack(&raw, 1, kept);
                signsgd::decode_sign(&codes)
            }
            CodecKind::SignSgdNorm => {
                let codes = bitpack::unpack(&raw, 1, kept);
                signsgd::decode_sign_norm(&codes, enc.norm)
            }
            CodecKind::EfSignSgd => {
                let codes = bitpack::unpack(&raw, 1, kept);
                signsgd::decode_ef(&codes, enc.bound)
            }
        };

        if enc.mask_seed != 0 && kept < n {
            let m = sparsify::mask(enc.mask_seed, n, kept as f64 / n as f64);
            anyhow::ensure!(
                m.kept.len() == kept,
                "mask regeneration mismatch: {} vs {kept}",
                m.kept.len()
            );
            Ok(sparsify::scatter(&values, &m))
        } else {
            Ok(values)
        }
    }
}

/// Number of kept coordinates when Hadamard padding applies (the rotated
/// codec transmits the padded vector).
impl Codec {
    /// Codes actually transmitted for `n`-element gradients (pre-pack).
    pub fn transmitted_codes(&self, n: usize) -> usize {
        let kept = if self.keep_frac < 1.0 {
            sparsify::kept_count(n, self.keep_frac)
        } else {
            n
        };
        match self.kind {
            CodecKind::LinearRotated { .. } => hadamard::padded_len(kept.max(1)),
            _ => kept,
        }
    }
}

/// EF + mask interplay: fold the residual into the gradient, compute the
/// global sign scale, gather kept coordinates, and update the residual for
/// ALL coordinates (unsent ones keep their full value as residual).
fn ef_pre_mask(g: &[f32], m: &sparsify::Mask, state: &mut ClientCodecState) -> Vec<f32> {
    if state.ef.residual.len() != g.len() {
        state.ef = ErrorFeedback::new(g.len());
    }
    let p: Vec<f32> = g
        .iter()
        .zip(&state.ef.residual)
        .map(|(&gi, &ei)| gi + ei)
        .collect();
    let kept_p = sparsify::gather(&p, m);
    let scale = kept_p.iter().map(|x| x.abs()).sum::<f32>() / kept_p.len().max(1) as f32;
    state.last_scale = scale;
    // Residual update: rec = scale·sign(p_i) on kept, 0 elsewhere.
    let mut kept_iter = m.kept.iter().peekable();
    for (i, (ei, &pi)) in state.ef.residual.iter_mut().zip(&p).enumerate() {
        let rec = if kept_iter.peek() == Some(&&i) {
            kept_iter.next();
            if pi >= 0.0 {
                scale
            } else {
                -scale
            }
        } else {
            0.0
        };
        *ei = pi - rec;
    }
    kept_p
}

/// Per-client codec memory.
#[derive(Debug, Clone, Default)]
pub struct ClientCodecState {
    pub ef: ErrorFeedback,
    last_scale: f32,
}

impl ClientCodecState {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A compressed gradient as it travels client → server.
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedGradient {
    pub kind_id: u8,
    pub bits: u8,
    pub n: u32,
    pub kept: u32,
    pub mask_seed: u64,
    pub rot_seed: u64,
    pub norm: f32,
    pub bound: f32,
    pub deflated: bool,
    pub payload: Vec<u8>,
}

impl EncodedGradient {
    /// Total bytes on the wire (header + payload) — the quantity every
    /// cost table in the paper measures. See [`super::wire`] for the
    /// exact serialization this counts.
    pub fn wire_bytes(&self) -> usize {
        super::wire::HEADER_BYTES + self.payload.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::gradient_like;
    use crate::util::stats::l2_norm;

    fn state() -> ClientCodecState {
        ClientCodecState::new()
    }

    fn rel_err(a: &[f32], b: &[f32]) -> f64 {
        let diff: f64 = a
            .iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        diff / l2_norm(a).max(1e-12)
    }

    #[test]
    fn cosine_8bit_roundtrip_accurate() {
        // Per-element angle error is ≤ q/2, so the L2 relative error scales
        // like sqrt(n/3)·q/2 ≈ 0.35 at n=10k — assert we stay within that
        // analytic envelope and that the *direction* is well preserved.
        let mut rng = Pcg64::seeded(111);
        let g = gradient_like(&mut rng, 10_000);
        // Auto bound (no saturation) so every element obeys the envelope;
        // top-p% clipping deliberately sacrifices the top tail (Table 2).
        let codec = Codec::new(CodecKind::Cosine {
            bits: 8,
            rounding: Rounding::Biased,
            bound: BoundMode::Auto,
        });
        let enc = codec.encode(&g, &mut state(), &mut rng);
        let dec = codec.decode(&enc).unwrap();
        assert_eq!(dec.len(), g.len());
        let q = (std::f32::consts::PI - 2.0 * enc.bound) / 255.0;
        let envelope = ((g.len() as f64) / 3.0).sqrt() * (q as f64) / 2.0 * 1.2 + 1e-3;
        assert!(
            rel_err(&g, &dec) < envelope,
            "rel err {} > envelope {envelope}",
            rel_err(&g, &dec)
        );
        let dot: f64 = g.iter().zip(&dec).map(|(&x, &y)| (x * y) as f64).sum();
        let cos_sim = dot / (l2_norm(&g) * l2_norm(&dec)).max(1e-12);
        assert!(cos_sim > 0.93, "cosine similarity {cos_sim}");
    }

    #[test]
    fn clipping_concentrates_error_on_top_tail() {
        // With top-1% clipping the saturated elements absorb the error while
        // the bulk is reconstructed finely — the paper's Table 2 mechanism.
        let mut rng = Pcg64::seeded(211);
        let g = gradient_like(&mut rng, 10_000);
        let codec = Codec::cosine(8);
        let enc = codec.encode(&g, &mut state(), &mut rng);
        let dec = codec.decode(&enc).unwrap();
        let k = 100; // top 1%
        let thresh = crate::util::stats::kth_largest_abs(&g, k);
        let (mut bulk_err, mut bulk_scale, mut nbulk) = (0.0f64, 0.0f64, 0usize);
        for (&a, &b) in g.iter().zip(&dec) {
            if a.abs() < thresh {
                bulk_err += ((a - b) as f64).powi(2);
                bulk_scale += (a as f64).powi(2);
                nbulk += 1;
            }
        }
        assert!(nbulk >= 9_800);
        // Bulk relative error stays small even though the tail saturates.
        let bulk_rel = (bulk_err / bulk_scale.max(1e-12)).sqrt();
        assert!(bulk_rel < 0.25, "bulk rel err {bulk_rel}");
    }

    #[test]
    fn all_codecs_roundtrip_dense_shape() {
        let mut rng = Pcg64::seeded(112);
        let g = gradient_like(&mut rng, 3000);
        let kinds = [
            CodecKind::Float32,
            CodecKind::Cosine {
                bits: 2,
                rounding: Rounding::Unbiased,
                bound: BoundMode::Auto,
            },
            CodecKind::Linear {
                bits: 4,
                rounding: Rounding::Biased,
            },
            CodecKind::LinearRotated {
                bits: 2,
                rounding: Rounding::Unbiased,
            },
            CodecKind::SignSgd,
            CodecKind::SignSgdNorm,
            CodecKind::EfSignSgd,
        ];
        for kind in kinds {
            for keep in [1.0, 0.25] {
                let codec = Codec::new(kind).with_sparsify(keep);
                let mut st = state();
                let enc = codec.encode(&g, &mut st, &mut rng);
                let dec = codec.decode(&enc).unwrap();
                assert_eq!(dec.len(), g.len(), "{}", codec.name());
                if keep < 1.0 {
                    let zeros = dec.iter().filter(|&&x| x == 0.0).count();
                    assert!(
                        zeros >= (g.len() as f64 * 0.7) as usize,
                        "{}: sparsified decode should be mostly zero ({zeros})",
                        codec.name()
                    );
                }
            }
        }
    }

    #[test]
    fn float32_roundtrip_exact() {
        let mut rng = Pcg64::seeded(113);
        let g = gradient_like(&mut rng, 513);
        let codec = Codec::float32();
        let enc = codec.encode(&g, &mut state(), &mut rng);
        assert_eq!(codec.decode(&enc).unwrap(), g);
    }

    #[test]
    fn sparsified_decode_preserves_kept_exactly_float32() {
        let mut rng = Pcg64::seeded(114);
        let g = gradient_like(&mut rng, 800);
        let codec = Codec::float32().with_sparsify(0.1);
        let enc = codec.encode(&g, &mut state(), &mut rng);
        let dec = codec.decode(&enc).unwrap();
        let m = sparsify::mask(enc.mask_seed, g.len(), 0.1);
        for &i in &m.kept {
            assert_eq!(dec[i], g[i]);
        }
        assert_eq!(dec.iter().filter(|&&x| x != 0.0).count(), m.kept.len());
    }

    #[test]
    fn rotated_linear_beats_plain_linear_with_outlier() {
        // The rotation's raison d'être: a dominating coordinate ruins plain
        // linear 2-bit; rotation spreads it.
        let mut rng = Pcg64::seeded(115);
        let mut g = gradient_like(&mut rng, 4096);
        g[7] = 25.0;
        let plain = Codec::new(CodecKind::Linear {
            bits: 2,
            rounding: Rounding::Unbiased,
        });
        let rotated = Codec::new(CodecKind::LinearRotated {
            bits: 2,
            rounding: Rounding::Unbiased,
        });
        let mut e_plain = 0.0;
        let mut e_rot = 0.0;
        for _ in 0..5 {
            let dp = plain
                .decode(&plain.encode(&g, &mut state(), &mut rng))
                .unwrap();
            let dr = rotated
                .decode(&rotated.encode(&g, &mut state(), &mut rng))
                .unwrap();
            e_plain += rel_err(&g, &dp);
            e_rot += rel_err(&g, &dr);
        }
        assert!(e_rot < e_plain, "rot {e_rot} !< plain {e_plain}");
    }

    #[test]
    fn cosine_2bit_beats_linear_2bit_biased() {
        // Figures 6/7 (a) in miniature: biased linear 2-bit reconstruction
        // is much worse than biased cosine 2-bit on gradient-like data.
        let mut rng = Pcg64::seeded(116);
        let g = gradient_like(&mut rng, 20_000);
        let cos = Codec::cosine(2);
        let lin = Codec::new(CodecKind::Linear {
            bits: 2,
            rounding: Rounding::Biased,
        });
        let dc = cos.decode(&cos.encode(&g, &mut state(), &mut rng)).unwrap();
        let dl = lin.decode(&lin.encode(&g, &mut state(), &mut rng)).unwrap();
        // Compare cosine similarity with the true gradient (direction is
        // what matters for SGD).
        let cs = |a: &[f32], b: &[f32]| {
            let dot: f64 = a.iter().zip(b).map(|(&x, &y)| (x * y) as f64).sum();
            dot / (l2_norm(a) * l2_norm(b)).max(1e-12)
        };
        assert!(
            cs(&g, &dc) > cs(&g, &dl),
            "cosine sim {} !> linear sim {}",
            cs(&g, &dc),
            cs(&g, &dl)
        );
    }

    #[test]
    fn wire_cost_reduction_matches_bits() {
        let mut rng = Pcg64::seeded(117);
        let g = gradient_like(&mut rng, 100_000);
        let f32_cost = Codec::float32()
            .encode(&g, &mut state(), &mut rng)
            .wire_bytes();
        let q8 = Codec::cosine(8).without_deflate();
        let cost8 = q8.encode(&g, &mut state(), &mut rng).wire_bytes();
        let ratio = f32_cost as f64 / cost8 as f64;
        assert!((3.5..4.5).contains(&ratio), "8-bit ratio {ratio}");
        // With DEFLATE the paper reports >10x total for 8-bit (Fig. 5).
        let q8d = Codec::cosine(8);
        let cost8d = q8d.encode(&g, &mut state(), &mut rng).wire_bytes();
        let ratio_d = f32_cost as f64 / cost8d as f64;
        assert!(ratio_d > 6.0, "deflated 8-bit ratio {ratio_d}");
    }

    #[test]
    fn deflate_flag_falls_back_when_incompressible() {
        let mut rng = Pcg64::seeded(118);
        let g = gradient_like(&mut rng, 4000);
        let enc = Codec::float32()
            .with_sparsify(1.0)
            .encode(&g, &mut state(), &mut rng);
        assert!(!enc.deflated); // float32() disables deflate
    }

    #[test]
    fn ef_with_mask_keeps_residual_for_unsent() {
        let mut rng = Pcg64::seeded(119);
        let g = vec![1.0f32; 64];
        let codec = Codec::new(CodecKind::EfSignSgd).with_sparsify(0.25);
        let mut st = state();
        let enc = codec.encode(&g, &mut st, &mut rng);
        let dec = codec.decode(&enc).unwrap();
        // Unsent coordinates: residual should hold their full value.
        let m = sparsify::mask(enc.mask_seed, g.len(), 0.25);
        let kept: std::collections::HashSet<usize> = m.kept.iter().copied().collect();
        for i in 0..g.len() {
            if !kept.contains(&i) {
                assert_eq!(dec[i], 0.0);
                assert!((st.ef.residual[i] - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn transmitted_codes_counts() {
        let c = Codec::cosine(2).with_sparsify(0.05);
        assert_eq!(c.transmitted_codes(1000), 50);
        let r = Codec::new(CodecKind::LinearRotated {
            bits: 2,
            rounding: Rounding::Unbiased,
        })
        .with_sparsify(0.05);
        assert_eq!(r.transmitted_codes(1000), 64); // padded to pow2
    }
}
