//! **CosSGD** — the paper's contribution: nonlinear gradient quantization in
//! the *angle* domain (§3).
//!
//! For a gradient vector `g`, each coordinate's angle to its axis is
//! `θ_i = arccos(g_i / ‖g‖₂) ∈ [0, π]`. A bound
//! `b_θ = min(min Θ, π − max Θ)` (optionally from a top-p%-clipped
//! distribution, Fig. 2) trims the empty ends, and `Θ` is quantized
//! *uniformly in angle* on `[b_θ, π − b_θ]` with `s` bits — which is
//! *non-uniform in value*: `cos` is flat near the interval ends (large
//! |g|), so large gradients get finer value resolution (Eq. 4) — the paper's
//! key property. At 1 bit the scheme degenerates to signSGD+Norm.
//!
//! Encoding detail: the paper's Eq. (3) scales by `2^s`, which produces
//! `2^s + 1` levels (code `2^s` occurs at `θ = π − b`) and does not fit in
//! `s` bits. We scale by `2^s − 1` so codes span exactly `0..2^s` — the
//! standard uniform-quantizer convention, preserving the construction
//! (and the 1-bit degenerate case) while keeping the wire format honest.

use crate::util::rng::Pcg64;
use crate::util::stats::{kth_largest_abs, l2_norm};

use super::kernel::{self, KernelScratch};

use std::f32::consts::PI;

/// How the angle bound `b_θ` is obtained (§3, Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BoundMode {
    /// `b_θ = min(min Θ, π − max Θ)` from the raw distribution.
    Auto,
    /// Clip the top `p`% of |g| first: the bound comes from the `⌈p% · n⌉`-th
    /// largest magnitude; larger values saturate at the boundary bins.
    /// The paper's default is `ClipTopPercent(1.0)` (§5).
    ClipTopPercent(f64),
    /// Fixed angle bound in `[0, π/2)` (ablations).
    FixedAngle(f32),
}

/// Deterministic (biased) round-to-nearest, or the probabilistic unbiased
/// regime of Eq. (3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    Biased,
    Unbiased,
}

/// Configuration of the cosine quantizer.
#[derive(Debug, Clone, Copy)]
pub struct CosineQuantizer {
    pub bits: u8,
    pub rounding: Rounding,
    pub bound: BoundMode,
}

impl CosineQuantizer {
    pub fn new(bits: u8, rounding: Rounding, bound: BoundMode) -> Self {
        assert!((1..=16).contains(&bits), "bits must be in 1..=16");
        Self {
            bits,
            rounding,
            bound,
        }
    }

    /// Paper default: biased, top-1% clipping (§5 "by default").
    pub fn paper_default(bits: u8) -> Self {
        Self::new(bits, Rounding::Biased, BoundMode::ClipTopPercent(1.0))
    }

    /// Number of quantization levels (`2^s`).
    pub fn levels(&self) -> u32 {
        1u32 << self.bits
    }

    /// Quantize a gradient vector. Returns codes (one per element) plus the
    /// two floats the server needs to invert the mapping.
    ///
    /// Fast path: for [`Rounding::Biased`] the nonlinear map is replaced by
    /// the transcendental-free threshold search of [`super::kernel`] —
    /// bit-identical to [`Self::quantize_reference`] (property-tested in
    /// `tests/kernel_equivalence.rs`). [`Rounding::Unbiased`] draws a
    /// uniform per element, so it keeps the reference `acos` loop.
    pub fn quantize(&self, g: &[f32], rng: &mut Pcg64) -> CosineQuantized {
        let mut scratch = KernelScratch::new();
        let mut codes = Vec::new();
        let (norm, bound) = self.quantize_into(g, rng, &mut scratch, &mut codes);
        CosineQuantized {
            codes,
            norm,
            bound,
            bits: self.bits,
        }
    }

    /// Fast-path quantize writing into reusable buffers (the pipeline's
    /// steady-state entry point). Returns `(norm, bound)`.
    pub fn quantize_into(
        &self,
        g: &[f32],
        rng: &mut Pcg64,
        scratch: &mut KernelScratch,
        codes: &mut Vec<u16>,
    ) -> (f32, f32) {
        let n = g.len();
        codes.clear();
        let norm = l2_norm(g) as f32;
        if !(norm.is_finite() && norm > 0.0) {
            // Zero (or non-finite) gradient: encode as all-zero with norm 0;
            // dequantize reproduces the zero vector exactly.
            codes.resize(n, 0);
            return (0.0, 0.0);
        }
        let bound = self.compute_bound(g, norm);
        match self.rounding {
            Rounding::Biased => {
                kernel::quantize_cosine_biased(g, norm, bound, self.bits, scratch, codes);
            }
            Rounding::Unbiased => {
                quantize_unbiased_reference(g, norm, bound, self.bits, rng, codes);
            }
        }
        (norm, bound)
    }

    /// The reference `acos`-per-element encode — the kernel's ground truth
    /// (and the only unbiased implementation). Kept callable for the
    /// equivalence property tests and the perf-trajectory benchmarks.
    pub fn quantize_reference(&self, g: &[f32], rng: &mut Pcg64) -> CosineQuantized {
        let n = g.len();
        let norm = l2_norm(g) as f32;
        if !(norm.is_finite() && norm > 0.0) {
            return CosineQuantized {
                codes: vec![0; n],
                norm: 0.0,
                bound: 0.0,
                bits: self.bits,
            };
        }

        let bound = self.compute_bound(g, norm);
        let max_code = (self.levels() - 1) as f32;
        let range = PI - 2.0 * bound;
        // Degenerate range (all angles identical): emit code 0 everywhere.
        let inv_range = if range > 1e-6 { 1.0 / range } else { 0.0 };

        // Perf (§Perf in EXPERIMENTS.md): hoist the division out of the
        // loop — one multiply per element instead of a divide; acos still
        // dominates but this shaves ~10% off the biased encode.
        let inv_norm = 1.0 / norm;
        let scale = inv_range * max_code;
        let mut codes = Vec::with_capacity(n);
        match self.rounding {
            Rounding::Biased => {
                for &gi in g {
                    let theta =
                        (gi * inv_norm).clamp(-1.0, 1.0).acos().clamp(bound, PI - bound);
                    let v = (theta - bound) * scale;
                    codes.push((v + 0.5) as u16); // round-to-nearest, v >= 0
                }
            }
            Rounding::Unbiased => {
                quantize_unbiased_reference(g, norm, bound, self.bits, rng, &mut codes);
            }
        }
        CosineQuantized {
            codes,
            norm,
            bound,
            bits: self.bits,
        }
    }

    pub(crate) fn compute_bound(&self, g: &[f32], norm: f32) -> f32 {
        match self.bound {
            BoundMode::Auto => {
                let (mut tmin, mut tmax) = (PI, 0.0f32);
                for &gi in g {
                    let t = angle(gi, norm);
                    tmin = tmin.min(t);
                    tmax = tmax.max(t);
                }
                // Paper: b_θ = min(min Θ, π − max Θ).
                tmin.min(PI - tmax).clamp(0.0, PI / 2.0)
            }
            BoundMode::ClipTopPercent(p) => {
                let k = ((p / 100.0) * g.len() as f64).ceil().max(1.0) as usize;
                let k = k.min(g.len());
                let clip = kth_largest_abs(g, k);
                angle(clip.min(norm), norm).clamp(0.0, PI / 2.0)
            }
            BoundMode::FixedAngle(b) => b.clamp(0.0, PI / 2.0 - 1e-6),
        }
    }
}

/// θ = arccos(g/‖g‖), clamped against float slop at ±1.
#[inline]
fn angle(gi: f32, norm: f32) -> f32 {
    (gi / norm).clamp(-1.0, 1.0).acos()
}

/// The probabilistic regime of Eq. (3): codes are a function of a uniform
/// draw per element, so there is no transcendental-free table for it —
/// this single implementation backs both the fast and reference entry
/// points. `norm` must be finite and positive.
fn quantize_unbiased_reference(
    g: &[f32],
    norm: f32,
    bound: f32,
    bits: u8,
    rng: &mut Pcg64,
    codes: &mut Vec<u16>,
) {
    let max_code = ((1u32 << bits) - 1) as f32;
    let range = PI - 2.0 * bound;
    let inv_range = if range > 1e-6 { 1.0 / range } else { 0.0 };
    let inv_norm = 1.0 / norm;
    let scale = inv_range * max_code;
    codes.reserve(g.len());
    // Perf: one 64-bit PCG draw yields two 24-bit uniforms —
    // halves the RNG cost of stochastic rounding.
    let mut pending: Option<f32> = None;
    for &gi in g {
        let theta = (gi * inv_norm).clamp(-1.0, 1.0).acos().clamp(bound, PI - bound);
        let v = (theta - bound) * scale;
        let f = v.floor();
        let p = v - f;
        let u = match pending.take() {
            Some(u) => u,
            None => {
                let word = rng.next_u64();
                const S: f32 = 1.0 / (1u32 << 24) as f32;
                pending = Some(((word >> 40) as u32) as f32 * S);
                ((word as u32) >> 8) as f32 * S
            }
        };
        let up = (u < p) as u16;
        codes.push(((f as u16) + up).min(max_code as u16));
    }
}

/// The output of [`CosineQuantizer::quantize`].
#[derive(Debug, Clone)]
pub struct CosineQuantized {
    pub codes: Vec<u16>,
    pub norm: f32,
    pub bound: f32,
    pub bits: u8,
}

impl CosineQuantized {
    /// Invert the quantization on the server (Algorithm 1 line 7):
    /// `g'_i = cos(code_i · (π − 2b)/(2^s − 1) + b) · ‖g‖₂`.
    pub fn dequantize(&self) -> Vec<f32> {
        dequantize_codes(&self.codes, self.norm, self.bound, self.bits)
    }

    /// Width of one angle interval, `q = (π − 2b)/(2^s − 1)`.
    pub fn interval_width(&self) -> f32 {
        (PI - 2.0 * self.bound) / ((1u32 << self.bits) - 1) as f32
    }
}

/// Server-side reconstruction from raw codes (shared with the wire
/// decoder). LUT-backed: only `2^s` distinct values exist per tensor, so
/// the kernel evaluates `cos` once per level instead of once per element
/// (bit-identical — each LUT entry is the per-element formula).
pub fn dequantize_codes(codes: &[u16], norm: f32, bound: f32, bits: u8) -> Vec<f32> {
    let mut out = Vec::new();
    dequantize_codes_into(codes, norm, bound, bits, &mut KernelScratch::new(), &mut out);
    out
}

/// [`dequantize_codes`] into reusable buffers (steady-state decode path).
pub fn dequantize_codes_into(
    codes: &[u16],
    norm: f32,
    bound: f32,
    bits: u8,
    scratch: &mut KernelScratch,
    out: &mut Vec<f32>,
) {
    kernel::dequantize_cosine(codes, norm, bound, bits, scratch, out);
}

// ---------------------------------------------------------------------------
// Analytic properties (§3.1) — drive Fig. 3 and the property tests.
// ---------------------------------------------------------------------------

/// Eq. (4): the value-space error bound for the k-th angle interval
/// (counting from the `b` end), interval width `q`, at unit norm:
/// `2 · sin(q(k + 3/4)) · sin(q/4)`.
pub fn cosine_error_bound(k: u32, q: f64, bound: f64) -> f64 {
    2.0 * ((bound + q * (k as f64 + 0.75)).sin()) * (q * 0.25).sin()
}

/// Error bound of *biased linear* quantization at `s` bits over
/// `[-b_g, b_g]` with `b_g = cos(b_θ)·‖g‖` (paper §3.1), at unit norm.
pub fn linear_error_bound(bits: u8, bound: f64) -> f64 {
    bound.cos() / (1u64 << bits) as f64
}

/// Eq. (5): count the intervals where the cosine quantizer's bound beats the
/// linear quantizer's. Returns `(winning, total)` — the paper reports
/// 50% / 42.9% / 44.1% for 2/4/8 bits (bound 0).
pub fn intervals_cosine_beats_linear(bits: u8, bound: f64) -> (u32, u32) {
    let total = 1u32 << bits;
    let q = (std::f64::consts::PI - 2.0 * bound) / total as f64;
    let lin = linear_error_bound(bits, bound);
    let winning = (0..total)
        .filter(|&k| cosine_error_bound(k, q, bound) < lin)
        .count() as u32;
    (winning, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{forall, gradient_like};

    fn q(bits: u8, rounding: Rounding) -> CosineQuantizer {
        CosineQuantizer::new(bits, rounding, BoundMode::Auto)
    }

    #[test]
    fn fast_biased_path_matches_reference() {
        // The full adversarial sweep lives in tests/kernel_equivalence.rs;
        // this is the in-module smoke version.
        let mut rng = Pcg64::seeded(77);
        let g = gradient_like(&mut rng, 3000);
        for bits in [1u8, 2, 4, 8, 12, 16] {
            for bound in [BoundMode::Auto, BoundMode::ClipTopPercent(1.0)] {
                let quant_cfg = CosineQuantizer::new(bits, Rounding::Biased, bound);
                let fast = quant_cfg.quantize(&g, &mut Pcg64::seeded(1));
                let refr = quant_cfg.quantize_reference(&g, &mut Pcg64::seeded(1));
                assert_eq!(fast.codes, refr.codes, "bits={bits} bound={bound:?}");
                assert_eq!(fast.norm.to_bits(), refr.norm.to_bits());
                assert_eq!(fast.bound.to_bits(), refr.bound.to_bits());
            }
        }
    }

    #[test]
    fn unbiased_fast_entry_matches_reference_stream() {
        // Unbiased keeps the acos path; the two entry points must consume
        // the RNG identically.
        let mut rng = Pcg64::seeded(78);
        let g = gradient_like(&mut rng, 500);
        let quant_cfg = q(4, Rounding::Unbiased);
        let fast = quant_cfg.quantize(&g, &mut Pcg64::seeded(2));
        let refr = quant_cfg.quantize_reference(&g, &mut Pcg64::seeded(2));
        assert_eq!(fast.codes, refr.codes);
    }

    #[test]
    fn exact_on_two_point_vector() {
        // n=1..2 edge cases reconstruct the extreme angles exactly.
        let mut rng = Pcg64::seeded(1);
        let g = vec![3.0f32, -4.0];
        let quant = q(4, Rounding::Biased).quantize(&g, &mut rng);
        let back = quant.dequantize();
        assert!((quant.norm - 5.0).abs() < 1e-6);
        for (a, b) in g.iter().zip(&back) {
            assert!((a - b).abs() < 0.3, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_vector_roundtrips_exactly() {
        let mut rng = Pcg64::seeded(2);
        let g = vec![0.0f32; 17];
        let quant = q(2, Rounding::Biased).quantize(&g, &mut rng);
        assert_eq!(quant.norm, 0.0);
        assert_eq!(quant.dequantize(), g);
    }

    #[test]
    fn angle_error_within_half_interval_biased() {
        let mut rng = Pcg64::seeded(3);
        let g = gradient_like(&mut rng, 4096);
        for bits in [2u8, 4, 8] {
            let quant = q(bits, Rounding::Biased).quantize(&g, &mut rng);
            let qw = quant.interval_width();
            let back = quant.dequantize();
            for (&gi, &bi) in g.iter().zip(&back) {
                let t = (gi / quant.norm).clamp(-1.0, 1.0).acos();
                let t_clamped = t.clamp(quant.bound, PI - quant.bound);
                let t_back = (bi / quant.norm).clamp(-1.0, 1.0).acos();
                assert!(
                    (t_clamped - t_back).abs() <= qw / 2.0 + 1e-4,
                    "bits={bits} angle err {} > q/2={}",
                    (t_clamped - t_back).abs(),
                    qw / 2.0
                );
            }
        }
    }

    #[test]
    fn value_error_below_lipschitz_bound() {
        // |cos(a)-cos(b)| <= |a-b|, so value error <= norm * q/2 (+ clip).
        let mut rng = Pcg64::seeded(4);
        forall(
            30,
            5,
            |rng2, size| { let n = size.len(rng2) * 8 + 4; gradient_like(rng2, n) },
            |g| {
                let quant = q(4, Rounding::Biased).quantize(g, &mut rng);
                let back = quant.dequantize();
                let tol = quant.norm * quant.interval_width() / 2.0 + 1e-5;
                g.iter().zip(&back).all(|(&a, &b)| {
                    // Elements clipped by the bound may exceed the interval
                    // bound but never the bound-to-extreme distance.
                    let t = (a / quant.norm).clamp(-1.0, 1.0).acos();
                    if t < quant.bound || t > PI - quant.bound {
                        return true; // saturated by design (Fig. 2 clipping)
                    }
                    (a - b).abs() <= tol
                })
            },
        );
    }

    #[test]
    fn larger_gradients_quantized_more_precisely() {
        // §3.1: |g1| > |g2|  ⇒  error bound of g1's interval is smaller.
        let qw = (std::f64::consts::PI) / 16.0;
        let mut bounds: Vec<f64> = (0..8).map(|k| cosine_error_bound(k, qw, 0.0)).collect();
        // Intervals 0..8 cover θ ∈ [0, π/2): decreasing |g|. Bounds must
        // increase toward π/2.
        for w in bounds.windows(2) {
            assert!(w[0] < w[1] + 1e-12);
        }
        bounds.reverse();
    }

    #[test]
    fn eq5_fractions_match_paper_shape() {
        // Paper (§3.1): top 50% / 42.9% / 44.1% of intervals beat linear for
        // 2/4/8 bits. Our 2^s−1 scaling shifts these slightly; assert the
        // shape: 2-bit exactly half, others in (0.38, 0.5).
        let (w2, t2) = intervals_cosine_beats_linear(2, 0.0);
        assert_eq!((w2, t2), (2, 4), "2-bit should win exactly half");
        for bits in [4u8, 8] {
            let (w, t) = intervals_cosine_beats_linear(bits, 0.0);
            let frac = w as f64 / t as f64;
            assert!((0.38..0.50).contains(&frac), "bits={bits} frac={frac}");
        }
    }

    #[test]
    fn one_bit_degenerates_to_sign_with_norm() {
        let mut rng = Pcg64::seeded(6);
        let g = gradient_like(&mut rng, 512);
        let quant = q(1, Rounding::Biased).quantize(&g, &mut rng);
        assert!(quant.codes.iter().all(|&c| c <= 1));
        let back = quant.dequantize();
        let a = (quant.bound.cos() * quant.norm).abs();
        for (&gi, &bi) in g.iter().zip(&back) {
            assert!((bi.abs() - a).abs() < 1e-4, "magnitude {} != {a}", bi.abs());
            if gi.abs() > 1e-6 {
                assert_eq!(bi.signum(), gi.signum(), "sign must be preserved");
            }
        }
    }

    #[test]
    fn unbiased_rounding_is_unbiased_in_angle() {
        let mut rng = Pcg64::seeded(7);
        let g = vec![0.03f32, -0.01, 0.005, 0.002, -0.04, 0.015, 0.001, -0.002];
        let quant_cfg = q(2, Rounding::Unbiased);
        let reps = 4000;
        let mut acc = vec![0.0f64; g.len()];
        let mut bound = 0.0f32;
        let mut norm = 0.0f32;
        for _ in 0..reps {
            let quant = quant_cfg.quantize(&g, &mut rng);
            bound = quant.bound;
            norm = quant.norm;
            let step = (PI - 2.0 * quant.bound) / 3.0;
            for (i, &c) in quant.codes.iter().enumerate() {
                acc[i] += (quant.bound + c as f32 * step) as f64;
            }
        }
        let qw = (PI - 2.0 * bound) / 3.0;
        for (i, &gi) in g.iter().enumerate() {
            let theta = (gi / norm).clamp(-1.0, 1.0).acos().clamp(bound, PI - bound) as f64;
            let mean = acc[i] / reps as f64;
            // Monte-Carlo tolerance: ~4σ of the Bernoulli mean.
            let tol = (qw as f64) * 4.0 / (reps as f64).sqrt() + 1e-4;
            assert!(
                (mean - theta).abs() < tol,
                "i={i} mean={mean} theta={theta} tol={tol}"
            );
        }
    }

    #[test]
    fn clipping_shrinks_the_quantization_range() {
        let mut rng = Pcg64::seeded(8);
        let mut g = gradient_like(&mut rng, 2000);
        g[0] = 50.0; // dominating coordinate (§3: "one dimension dominating")
        let auto = CosineQuantizer::new(8, Rounding::Biased, BoundMode::Auto)
            .quantize(&g, &mut rng);
        let clipped =
            CosineQuantizer::new(8, Rounding::Biased, BoundMode::ClipTopPercent(1.0))
                .quantize(&g, &mut rng);
        // Clipping ignores the dominator, so its bound is LARGER (narrower
        // angle range = finer bins for the bulk).
        assert!(
            clipped.bound > auto.bound,
            "clip bound {} <= auto bound {}",
            clipped.bound,
            auto.bound
        );
        assert!(clipped.interval_width() < auto.interval_width());
    }

    #[test]
    fn codes_fit_in_declared_bits() {
        let mut rng = Pcg64::seeded(9);
        let g = gradient_like(&mut rng, 1000);
        for bits in [1u8, 2, 4, 8] {
            for rounding in [Rounding::Biased, Rounding::Unbiased] {
                let quant = q(bits, rounding).quantize(&g, &mut rng);
                let max = (1u32 << bits) - 1;
                assert!(quant.codes.iter().all(|&c| (c as u32) <= max));
            }
        }
    }

    #[test]
    fn preserves_norm_scale_invariance() {
        // Quantizing 10*g gives 10x the reconstruction (angles unchanged).
        let mut rng = Pcg64::seeded(10);
        let g = gradient_like(&mut rng, 256);
        let g10: Vec<f32> = g.iter().map(|x| x * 10.0).collect();
        let a = q(4, Rounding::Biased).quantize(&g, &mut rng);
        let b = q(4, Rounding::Biased).quantize(&g10, &mut rng);
        assert_eq!(a.codes, b.codes);
        let (da, db) = (a.dequantize(), b.dequantize());
        for (x, y) in da.iter().zip(&db) {
            assert!((y - 10.0 * x).abs() < 1e-3 * a.norm.max(1.0));
        }
    }
}
