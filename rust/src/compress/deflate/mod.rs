//! DEFLATE (RFC 1951) implemented from scratch — the paper's lossless stage
//! (§4, Deutsch [10]).
//!
//! The paper's observation: *quantized* gradient codes have low byte-level
//! entropy (most codes cluster around the "zero-gradient" angle bin), so a
//! generic LZ77 + Huffman coder shrinks them a further 3–4×, while raw
//! float32 gradients barely compress (~1.07×). We therefore need a real
//! DEFLATE on the encode hot path; since no compression crate is available
//! offline for runtime use, this module implements the format:
//!
//! * [`lz77`] — hash-chain match finder (32 KiB window, lazy matching),
//! * [`matcher`] — chunked match finder for the parallel plane: fixed
//!   128 KiB chunks with a 32 KiB dictionary carry-in, one chunk = one
//!   block, bytes identical at every thread count,
//! * [`huffman`] — canonical code construction (length-limited) + decode
//!   tables, bit-level stream stitching (`BitWriter::append`),
//! * [`block`] — per-block writer choosing stored / fixed / dynamic by
//!   exact cost,
//! * [`encoder`] — orchestration: serial loop or scoped worker threads
//!   with static chunk striping and bounded per-worker channels
//!   (`deflate_into` streams completed bytes into the caller's buffer),
//! * [`decoder`] — a full inflate (stored, fixed and dynamic blocks).
//!
//! `flate2` (vendored for the `xla` crate) is used **in tests only** to
//! cross-validate both directions of our implementation against zlib.

pub mod block;
pub mod decoder;
pub mod encoder;
pub mod huffman;
pub mod lz77;
pub mod matcher;

pub use decoder::inflate;
pub use encoder::{deflate, deflate_into, CompressionLevel, DeflateStats};

/// Convenience: compress with the default level.
pub fn compress(data: &[u8]) -> Vec<u8> {
    deflate(data, CompressionLevel::Default)
}

/// Convenience: decompress, panicking on malformed input is avoided — this
/// returns a Result with a descriptive error.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>, decoder::InflateError> {
    inflate(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{bytes, compressible_bytes, forall};
    use crate::util::rng::Pcg64;

    #[test]
    fn empty_input() {
        let c = compress(&[]);
        assert_eq!(decompress(&c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn tiny_inputs() {
        for data in [&b"a"[..], b"ab", b"aaa", b"abcabcabc"] {
            let c = compress(data);
            assert_eq!(decompress(&c).unwrap(), data, "data={data:?}");
        }
    }

    #[test]
    fn long_runs_compress_hard() {
        let data = vec![7u8; 100_000];
        let c = compress(&data);
        assert!(c.len() < 600, "run of 100k bytes -> {} bytes", c.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn random_data_roundtrips_with_small_overhead() {
        let mut rng = Pcg64::seeded(71);
        let data = bytes(&mut rng, 50_000);
        let c = compress(&data);
        assert!(c.len() < data.len() + data.len() / 100 + 64);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn property_roundtrip_identity() {
        forall(
            60,
            72,
            |rng, size| {
                let n = size.len(rng) * 37;
                if rng.bernoulli(0.5) {
                    compressible_bytes(rng, n)
                } else {
                    bytes(rng, n)
                }
            },
            |data| decompress(&compress(data)).unwrap() == *data,
        );
    }

    #[test]
    fn quantized_gradient_codes_compress_much_better_than_floats() {
        // The paper's Figure 5 phenomenon, as a unit test.
        let mut rng = Pcg64::seeded(73);
        let g = crate::util::propcheck::gradient_like(&mut rng, 60_000);
        let quant = crate::compress::cosine::CosineQuantizer::paper_default(8)
            .quantize(&g, &mut rng);
        let packed = crate::compress::bitpack::pack(&quant.codes, 8);
        let float_bytes: Vec<u8> = g.iter().flat_map(|x| x.to_le_bytes()).collect();
        let ratio_codes = packed.len() as f64 / compress(&packed).len() as f64;
        let ratio_floats = float_bytes.len() as f64 / compress(&float_bytes).len() as f64;
        assert!(
            ratio_codes > 1.7 * ratio_floats && ratio_codes > 1.9,
            "codes ratio {ratio_codes:.2} vs floats ratio {ratio_floats:.2}"
        );
        assert!(ratio_floats < 1.6, "floats should barely compress");
    }

    // ---- cross-validation against zlib -----------------------------------
    // Behind the optional `zlib-yardstick` feature so offline builds need
    // no crates beyond the vendored tree:
    //     cargo test --features zlib-yardstick

    #[cfg(feature = "zlib-yardstick")]
    #[test]
    fn our_deflate_is_readable_by_zlib() {
        use std::io::Read;
        let mut rng = Pcg64::seeded(74);
        for n in [0usize, 1, 100, 5000, 70_000] {
            let data = compressible_bytes(&mut rng, n);
            let ours = compress(&data);
            let mut z = flate2::read::DeflateDecoder::new(&ours[..]);
            let mut out = Vec::new();
            z.read_to_end(&mut out).expect("zlib rejected our stream");
            assert_eq!(out, data, "n={n}");
        }
    }

    #[cfg(feature = "zlib-yardstick")]
    #[test]
    fn zlib_deflate_is_readable_by_us() {
        use std::io::Write;
        let mut rng = Pcg64::seeded(75);
        for n in [0usize, 1, 333, 10_000, 80_000] {
            let data = bytes(&mut rng, n);
            for level in [0u32, 1, 6, 9] {
                let mut e = flate2::write::DeflateEncoder::new(
                    Vec::new(),
                    flate2::Compression::new(level),
                );
                e.write_all(&data).unwrap();
                let zbytes = e.finish().unwrap();
                assert_eq!(
                    decompress(&zbytes).expect("we rejected zlib's stream"),
                    data,
                    "n={n} level={level}"
                );
            }
        }
    }

    #[cfg(feature = "zlib-yardstick")]
    #[test]
    fn compression_ratio_competitive_with_zlib() {
        use std::io::Write;
        let mut rng = Pcg64::seeded(76);
        let data = compressible_bytes(&mut rng, 120_000);
        let ours = compress(&data).len();
        let mut e =
            flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::new(6));
        e.write_all(&data).unwrap();
        let theirs = e.finish().unwrap().len();
        // Within 40% of zlib level 6 on the regime we care about.
        assert!(
            (ours as f64) < theirs as f64 * 1.4,
            "ours={ours} zlib={theirs}"
        );
    }
}
