//! Chunked LZ77 match finder for the parallel DEFLATE plane.
//!
//! The input is cut at fixed [`CHUNK_SIZE`] boundaries — a function of the
//! input length only, never of the thread count — and each chunk is
//! tokenized independently with up to `WINDOW_SIZE` bytes of preceding
//! input pre-inserted into the hash chains as a dictionary. Match lengths
//! are capped at the chunk end, so one chunk maps to exactly one DEFLATE
//! block and the concatenated blocks form a single valid stream whose
//! bytes are identical at every thread count (`tests/deflate_parallel.rs`
//! pins this at 1/4/8 threads).
//!
//! Dictionary carry-in keeps the candidate set of every chunk position
//! complete: any in-window back-reference target for a position `p` in the
//! chunk satisfies `p - dist >= chunk_start - WINDOW_SIZE`, which is
//! exactly the range walked by [`MatcherScratch::reset`]'s insert-only
//! pre-pass, so cutting the input into chunks costs no reachable matches —
//! only matches that would have *spanned* a chunk boundary are shortened.

use super::lz77::{
    hash3, match_len, MatchParams, Token, HASH_SIZE, MAX_INSERTS, MAX_MATCH, MIN_MATCH, NIL,
    WINDOW_SIZE,
};

/// Uncompressed bytes per chunk (= one DEFLATE block). Matches the serial
/// encoder's historical block span so single-chunk inputs are unchanged.
pub const CHUNK_SIZE: usize = 128 * 1024;

/// Number of fixed-size chunks covering `n` input bytes. At least one, so
/// the empty input still emits a (final) block.
pub fn chunk_count(n: usize) -> usize {
    n.div_ceil(CHUNK_SIZE).max(1)
}

/// Half-open input range of chunk `ci`.
pub fn chunk_range(n: usize, ci: usize) -> (usize, usize) {
    let start = ci * CHUNK_SIZE;
    (start.min(n), (start + CHUNK_SIZE).min(n))
}

/// Reusable hash-chain state. One per worker; `reset` re-primes it for the
/// next chunk without reallocating (the chunk loop stays allocation-free).
pub struct MatcherScratch {
    /// `head[h]` = most recent absolute position with hash `h`, or NIL.
    head: Vec<u32>,
    /// `prev[p - dict_start]` = previous position in `p`'s chain, or NIL.
    prev: Vec<u32>,
}

impl Default for MatcherScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl MatcherScratch {
    // analyze: allow(hotpath): one-time scratch construction, reused across every chunk a worker owns
    pub fn new() -> Self {
        MatcherScratch {
            head: vec![NIL; HASH_SIZE],
            prev: Vec::new(),
        }
    }

    /// Clear the chains and size `prev` for `span` positions.
    fn reset(&mut self, span: usize) {
        self.head.fill(NIL);
        self.prev.clear();
        self.prev.resize(span, NIL);
    }
}

#[inline]
fn insert(data: &[u8], pos: usize, dict_start: usize, head: &mut [u32], prev: &mut [u32]) {
    if pos + MIN_MATCH <= data.len() {
        let h = hash3(data, pos);
        prev[pos - dict_start] = head[h];
        head[h] = pos as u32;
    }
}

#[inline]
fn find_match(
    data: &[u8],
    pos: usize,
    dict_start: usize,
    end: usize,
    head: &[u32],
    prev: &[u32],
    params: &MatchParams,
) -> (usize, usize) {
    if pos + MIN_MATCH > data.len() {
        return (0, 0);
    }
    // Cap at the chunk end: a match may *reference* the dictionary but may
    // not cover bytes past the chunk, so one chunk stays one block.
    let max_len = MAX_MATCH.min(end - pos);
    let mut best_len = MIN_MATCH - 1;
    let mut best_dist = 0usize;
    let mut cand = head[hash3(data, pos)];
    let min_pos = pos.saturating_sub(WINDOW_SIZE);
    let mut chain = params.max_chain;
    while cand != NIL && (cand as usize) >= min_pos && chain > 0 {
        let c = cand as usize;
        if c >= pos {
            break;
        }
        // Quick reject: check the byte past the current best.
        if best_len < max_len && data[c + best_len] == data[pos + best_len] {
            let l = match_len(data, c, pos, max_len);
            if l > best_len {
                best_len = l;
                best_dist = pos - c;
                if l >= params.good_len {
                    break;
                }
            }
        }
        cand = prev[c - dict_start];
        chain -= 1;
    }
    if best_len >= MIN_MATCH {
        (best_len, best_dist)
    } else {
        (0, 0)
    }
}

/// Tokenize `data[start..end]` into `tokens` (cleared first), with
/// `data[max(0, start - WINDOW_SIZE)..start]` as the back-reference
/// dictionary. Same greedy/lazy discipline as `lz77::tokenize`, plus the
/// chunk-end match cap.
pub fn tokenize_chunk(
    data: &[u8],
    start: usize,
    end: usize,
    params: MatchParams,
    scratch: &mut MatcherScratch,
    tokens: &mut Vec<Token>,
) {
    tokens.clear();
    if start >= end {
        return;
    }
    let dict_start = start.saturating_sub(WINDOW_SIZE);
    if end - dict_start < MIN_MATCH + 1 {
        tokens.extend(data[start..end].iter().map(|&b| Token::Literal(b)));
        return;
    }
    scratch.reset(end - dict_start);
    let head = &mut scratch.head;
    let prev = &mut scratch.prev;

    // Insert-only walk over the dictionary: every window-reachable
    // predecessor of every chunk position lands in the chains.
    for p in dict_start..start {
        insert(data, p, dict_start, head, prev);
    }

    let mut i = start;
    while i < end {
        let (len, dist) = find_match(data, i, dict_start, end, head, prev, &params);
        if len == 0 {
            insert(data, i, dict_start, head, prev);
            tokens.push(Token::Literal(data[i]));
            i += 1;
            continue;
        }

        // Lazy matching: if the match starting at i+1 is strictly longer,
        // emit data[i] as a literal and defer.
        if params.lazy && len < params.good_len && i + 1 < end {
            insert(data, i, dict_start, head, prev);
            let (len2, _d2) = find_match(data, i + 1, dict_start, end, head, prev, &params);
            if len2 > len {
                tokens.push(Token::Literal(data[i]));
                i += 1;
                continue;
            }
            tokens.push(Token::Match {
                len: len as u16,
                dist: dist as u16,
            });
            // Perf: cap chain insertions per committed match, as in
            // `lz77::tokenize` (long runs would insert hundreds of
            // identical positions).
            let ins_end = (i + len).min(i + 1 + MAX_INSERTS);
            for p in i + 1..ins_end {
                insert(data, p, dict_start, head, prev);
            }
            i += len;
        } else {
            tokens.push(Token::Match {
                len: len as u16,
                dist: dist as u16,
            });
            let ins_end = (i + len).min(i + MAX_INSERTS);
            for p in i..ins_end {
                insert(data, p, dict_start, head, prev);
            }
            i += len;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::compressible_bytes;
    use crate::util::rng::Pcg64;

    fn expand_from(data: &[u8], start: usize, tokens: &[Token]) -> Vec<u8> {
        // Expand chunk tokens against the real preceding bytes (matches may
        // reference the dictionary region before `start`).
        let mut out = data[..start].to_vec();
        for t in tokens {
            match *t {
                Token::Literal(b) => out.push(b),
                Token::Match { len, dist } => {
                    let s = out.len() - dist as usize;
                    for k in 0..len as usize {
                        let b = out[s + k];
                        out.push(b);
                    }
                }
            }
        }
        out.split_off(start)
    }

    #[test]
    fn chunk_bounds_cover_the_input_exactly() {
        for n in [0usize, 1, CHUNK_SIZE - 1, CHUNK_SIZE, CHUNK_SIZE + 1, 3 * CHUNK_SIZE + 17] {
            let k = chunk_count(n);
            let mut covered = 0usize;
            for ci in 0..k {
                let (s, e) = chunk_range(n, ci);
                assert_eq!(s, covered, "n={n} ci={ci}");
                covered = e;
            }
            assert_eq!(covered, n);
            assert!(k >= 1);
        }
    }

    #[test]
    fn single_chunk_matches_serial_tokenizer() {
        let mut rng = Pcg64::seeded(101);
        let data = compressible_bytes(&mut rng, 50_000);
        for params in [
            MatchParams::fast(),
            MatchParams::default_level(),
            MatchParams::best(),
        ] {
            let serial = super::super::lz77::tokenize(&data, params);
            let mut scratch = MatcherScratch::new();
            let mut toks = Vec::new();
            tokenize_chunk(&data, 0, data.len(), params, &mut scratch, &mut toks);
            assert_eq!(toks, serial);
        }
    }

    #[test]
    fn chunk_tokens_expand_to_the_chunk_bytes() {
        let mut rng = Pcg64::seeded(102);
        let data = compressible_bytes(&mut rng, 3 * CHUNK_SIZE + 4321);
        let mut scratch = MatcherScratch::new();
        let mut toks = Vec::new();
        for ci in 0..chunk_count(data.len()) {
            let (s, e) = chunk_range(data.len(), ci);
            tokenize_chunk(&data, s, e, MatchParams::default_level(), &mut scratch, &mut toks);
            assert_eq!(expand_from(&data, s, &toks), data[s..e].to_vec(), "chunk {ci}");
            // One chunk = one block: no match may cover bytes past `e`.
            let mut pos = s;
            for t in &toks {
                pos += match *t {
                    Token::Literal(_) => 1,
                    Token::Match { len, .. } => len as usize,
                };
            }
            assert_eq!(pos, e);
        }
    }

    #[test]
    fn dictionary_carry_in_finds_cross_chunk_matches() {
        // A motif planted just before the chunk boundary must be reachable
        // as a back-reference from inside the next chunk.
        let motif = b"abcdefghijklmnopqrstuvwxyz012345";
        let mut data = vec![0u8; CHUNK_SIZE + 200];
        data[CHUNK_SIZE - 32..CHUNK_SIZE].copy_from_slice(motif);
        data[CHUNK_SIZE + 100..CHUNK_SIZE + 132].copy_from_slice(motif);
        let mut scratch = MatcherScratch::new();
        let mut toks = Vec::new();
        let (s, e) = chunk_range(data.len(), 1);
        tokenize_chunk(&data, s, e, MatchParams::default_level(), &mut scratch, &mut toks);
        let crosses = toks.iter().any(|t| match *t {
            Token::Match { dist, .. } => (dist as usize) > 100,
            _ => false,
        });
        assert!(crosses, "expected a back-reference into the dictionary: {toks:?}");
        assert_eq!(expand_from(&data, s, &toks), data[s..e].to_vec());
    }

    #[test]
    fn scratch_reuse_is_clean_across_chunks() {
        // Tokenizing chunk B after chunk A must equal tokenizing B fresh.
        let mut rng = Pcg64::seeded(103);
        let data = compressible_bytes(&mut rng, 2 * CHUNK_SIZE);
        let p = MatchParams::default_level();
        let (s, e) = chunk_range(data.len(), 1);
        let mut reused = MatcherScratch::new();
        let mut toks_a = Vec::new();
        tokenize_chunk(&data, 0, CHUNK_SIZE, p, &mut reused, &mut toks_a);
        let mut toks_reused = Vec::new();
        tokenize_chunk(&data, s, e, p, &mut reused, &mut toks_reused);
        let mut fresh = MatcherScratch::new();
        let mut toks_fresh = Vec::new();
        tokenize_chunk(&data, s, e, p, &mut fresh, &mut toks_fresh);
        assert_eq!(toks_reused, toks_fresh);
    }
}
