//! Full DEFLATE decoder (inflate): stored, fixed-Huffman and
//! dynamic-Huffman blocks (RFC 1951 §3.2).

use super::block::{
    fixed_dist_lengths, fixed_lit_lengths, CLEN_ORDER, DIST_TABLE, LENGTH_TABLE,
};
use super::huffman::{BitReader, BitsError, Decoder};

/// Inflate failure with a description (malformed stream, bad code, etc.).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InflateError(pub String);

impl std::fmt::Display for InflateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "inflate error: {}", self.0)
    }
}
impl std::error::Error for InflateError {}

impl From<BitsError> for InflateError {
    fn from(e: BitsError) -> Self {
        InflateError(e.0.to_string())
    }
}

/// Decompress a raw DEFLATE stream.
pub fn inflate(data: &[u8]) -> Result<Vec<u8>, InflateError> {
    let mut br = BitReader::new(data);
    let mut out: Vec<u8> = Vec::with_capacity(data.len() * 3);
    loop {
        let bfinal = br.read_bits(1)?;
        let btype = br.read_bits(2)?;
        match btype {
            0b00 => inflate_stored(&mut br, &mut out)?,
            0b01 => {
                let lit = Decoder::new(&fixed_lit_lengths())
                    .map_err(|e| InflateError(e.0.into()))?;
                let dist = Decoder::new(&fixed_dist_lengths())
                    .map_err(|e| InflateError(e.0.into()))?;
                inflate_block(&mut br, &mut out, &lit, &dist)?;
            }
            0b10 => {
                let (lit, dist) = read_dynamic_tables(&mut br)?;
                inflate_block(&mut br, &mut out, &lit, &dist)?;
            }
            _ => return Err(InflateError("reserved block type 11".into())),
        }
        if bfinal == 1 {
            return Ok(out);
        }
    }
}

fn inflate_stored(br: &mut BitReader, out: &mut Vec<u8>) -> Result<(), InflateError> {
    br.align_byte();
    let len = br.read_u16()?;
    let nlen = br.read_u16()?;
    if len != !nlen {
        return Err(InflateError(format!(
            "stored block LEN/NLEN mismatch: {len:04x} vs {nlen:04x}"
        )));
    }
    br.read_bytes(len as usize, out)?;
    Ok(())
}

fn read_dynamic_tables(br: &mut BitReader) -> Result<(Decoder, Decoder), InflateError> {
    let hlit = br.read_bits(5)? as usize + 257;
    let hdist = br.read_bits(5)? as usize + 1;
    let hclen = br.read_bits(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err(InflateError(format!("bad HLIT/HDIST: {hlit}/{hdist}")));
    }
    let mut clen_len = vec![0u8; 19];
    for &ord in CLEN_ORDER.iter().take(hclen) {
        let v = br.read_bits(3)? as u8;
        *clen_len
            .get_mut(ord)
            .ok_or_else(|| InflateError(format!("clen order index {ord} out of range")))? = v;
    }
    let clen_dec = Decoder::new(&clen_len).map_err(|e| InflateError(e.0.into()))?;

    // Read hlit + hdist code lengths via the RLE alphabet.
    let total = hlit + hdist;
    let mut lengths = Vec::with_capacity(total);
    while lengths.len() < total {
        let sym = clen_dec.decode(br)?;
        match sym {
            0..=15 => lengths.push(sym as u8),
            16 => {
                let prev = *lengths
                    .last()
                    .ok_or_else(|| InflateError("16 with no previous length".into()))?;
                let rep = 3 + br.read_bits(2)? as usize;
                lengths.extend(std::iter::repeat_n(prev, rep));
            }
            17 => {
                let rep = 3 + br.read_bits(3)? as usize;
                lengths.extend(std::iter::repeat_n(0u8, rep));
            }
            18 => {
                let rep = 11 + br.read_bits(7)? as usize;
                lengths.extend(std::iter::repeat_n(0u8, rep));
            }
            _ => return Err(InflateError(format!("bad clen symbol {sym}"))),
        }
    }
    if lengths.len() != total {
        return Err(InflateError("code length RLE overran".into()));
    }
    let lit_lens = lengths
        .get(..hlit)
        .ok_or_else(|| InflateError("code length RLE underran HLIT".into()))?;
    let dist_lens = lengths
        .get(hlit..)
        .ok_or_else(|| InflateError("code length RLE underran HDIST".into()))?;
    let lit_dec = Decoder::new(lit_lens).map_err(|e| InflateError(e.0.into()))?;
    let dist_dec = Decoder::new(dist_lens).map_err(|e| InflateError(e.0.into()))?;
    Ok((lit_dec, dist_dec))
}

fn inflate_block(
    br: &mut BitReader,
    out: &mut Vec<u8>,
    lit: &Decoder,
    dist: &Decoder,
) -> Result<(), InflateError> {
    loop {
        let sym = lit.decode(br)?;
        match sym {
            0..=255 => out.push(sym as u8),
            256 => return Ok(()),
            257..=285 => {
                let (base, extra) = *LENGTH_TABLE
                    .get(sym as usize - 257)
                    .ok_or_else(|| InflateError(format!("bad length symbol {sym}")))?;
                let len = base as usize + br.read_bits(extra as u32)? as usize;
                let dsym = dist.decode(br)?;
                let (dbase, dextra) = *DIST_TABLE
                    .get(dsym as usize)
                    .ok_or_else(|| InflateError(format!("bad distance symbol {dsym}")))?;
                let d = dbase as usize + br.read_bits(dextra as u32)? as usize;
                if d > out.len() {
                    return Err(InflateError(format!(
                        "distance {d} exceeds output length {}",
                        out.len()
                    )));
                }
                let start = out.len() - d;
                // Overlapping copies are the norm (run-length via dist 1).
                for k in 0..len {
                    let b = *out
                        .get(start + k)
                        .ok_or_else(|| InflateError("copy source out of range".into()))?;
                    out.push(b);
                }
            }
            _ => return Err(InflateError(format!("bad literal/length symbol {sym}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::encoder::{deflate, CompressionLevel};
    use super::*;

    #[test]
    fn inflate_stored_block() {
        // Hand-built: BFINAL=1, BTYPE=00, align, LEN=3, NLEN=~3, "abc".
        let mut bytes = vec![0b0000_0001u8];
        bytes.extend_from_slice(&3u16.to_le_bytes());
        bytes.extend_from_slice(&(!3u16).to_le_bytes());
        bytes.extend_from_slice(b"abc");
        assert_eq!(inflate(&bytes).unwrap(), b"abc");
    }

    #[test]
    fn rejects_len_nlen_mismatch() {
        let mut bytes = vec![0b0000_0001u8];
        bytes.extend_from_slice(&3u16.to_le_bytes());
        bytes.extend_from_slice(&0u16.to_le_bytes()); // wrong NLEN
        bytes.extend_from_slice(b"abc");
        assert!(inflate(&bytes).is_err());
    }

    #[test]
    fn rejects_reserved_block_type() {
        // BFINAL=1, BTYPE=11.
        assert!(inflate(&[0b0000_0111]).is_err());
    }

    #[test]
    fn rejects_truncated_stream() {
        let data = b"hello hello hello hello";
        let c = deflate(data, CompressionLevel::Default);
        for cut in 1..c.len().min(8) {
            assert!(
                inflate(&c[..c.len() - cut]).is_err(),
                "truncated by {cut} should fail"
            );
        }
    }

    #[test]
    fn rejects_distance_before_start() {
        // Fixed block: a match with distance 1 as the very first token.
        use super::super::block::{fixed_dist_lengths, fixed_lit_lengths};
        use super::super::huffman::{canonical_codes, BitWriter};
        let lit_len = fixed_lit_lengths();
        let dist_len = fixed_dist_lengths();
        let lit_codes = canonical_codes(&lit_len);
        let dist_codes = canonical_codes(&dist_len);
        let mut w = BitWriter::new();
        w.write_bits(1, 1);
        w.write_bits(0b01, 2);
        // length code 257 (len 3), distance code 0 (dist 1) with empty out.
        w.write_code(lit_codes[257], lit_len[257] as u32);
        w.write_code(dist_codes[0], dist_len[0] as u32);
        w.write_code(lit_codes[256], lit_len[256] as u32);
        assert!(inflate(&w.finish()).is_err());
    }

    #[test]
    fn multi_block_streams() {
        // > BLOCK_SPAN bytes forces multiple blocks.
        let data: Vec<u8> = (0..300_000u32).map(|i| (i % 251) as u8).collect();
        let c = deflate(&data, CompressionLevel::Fast);
        assert_eq!(inflate(&c).unwrap(), data);
    }
}
