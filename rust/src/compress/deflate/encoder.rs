//! DEFLATE encoder orchestration: fixed-size input chunks → chunked match
//! finder ([`super::matcher`]) → block writer ([`super::block`]), optionally
//! fanned out over scoped worker threads.
//!
//! Parallel discipline (mirrors `fl/ingest.rs`): chunk boundaries are a
//! function of the input length only; worker `w` owns chunks `w, w+T,
//! w+2T, …` (static striping, so per-thread stats are deterministic at a
//! fixed thread count); each worker sends finished per-chunk bit streams
//! down its own bounded channel; the calling thread stitches them in chunk
//! order with [`BitWriter::append`] and drains completed bytes straight
//! into the caller's output buffer. Because chunking, tokenization, and
//! block emission never consult the thread count, the output bytes are
//! identical at ANY thread count — `threads` only changes wall-clock.

use super::block::emit_block;
use super::huffman::BitWriter;
use super::lz77::{MatchParams, Token};
use super::matcher::{chunk_count, chunk_range, tokenize_chunk, MatcherScratch};

/// Compression effort.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressionLevel {
    Fast,
    Default,
    Best,
}

impl CompressionLevel {
    fn params(self) -> MatchParams {
        match self {
            CompressionLevel::Fast => MatchParams::fast(),
            CompressionLevel::Default => MatchParams::default_level(),
            CompressionLevel::Best => MatchParams::best(),
        }
    }

    /// CLI name (`--deflate-level`).
    pub fn name(self) -> &'static str {
        match self {
            CompressionLevel::Fast => "fast",
            CompressionLevel::Default => "default",
            CompressionLevel::Best => "best",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<CompressionLevel> {
        match s {
            "fast" => Some(CompressionLevel::Fast),
            "default" => Some(CompressionLevel::Default),
            "best" => Some(CompressionLevel::Best),
            _ => None,
        }
    }
}

/// What one `deflate_into` call did (fed into round telemetry and the
/// downlink broadcast observations).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DeflateStats {
    /// Chunks (= DEFLATE blocks at the chunk layer) emitted.
    pub chunks: u64,
    /// Uncompressed input bytes.
    pub bytes_in: u64,
    /// Compressed output bytes.
    pub bytes_out: u64,
    /// Worker threads actually used (≤ requested, ≤ chunk count).
    pub threads: usize,
    /// Compressed bytes contributed by each worker (len == `threads`).
    pub per_thread: Vec<u64>,
}

/// Bounded per-worker channel depth: enough to pipeline match-finding
/// ahead of stitching without holding many chunks in flight.
const CHANNEL_DEPTH: usize = 2;

/// Compress `data` into a raw DEFLATE stream (serial convenience wrapper).
pub fn deflate(data: &[u8], level: CompressionLevel) -> Vec<u8> {
    let mut out = Vec::new();
    deflate_into(data, level, 1, &mut out);
    out
}

/// Resolve the requested thread count: 0 = auto, and never more workers
/// than chunks. The resolution affects scheduling only, never the bytes.
fn effective_threads(requested: usize, nchunks: usize) -> usize {
    let t = match requested {
        0 => std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1),
        t => t,
    };
    t.clamp(1, nchunks)
}

/// Compress `data` appending the raw DEFLATE stream to `out` (streaming:
/// completed bytes land in `out` as chunks finish, so wire serialization
/// overlaps compression). Output bytes are identical at every `threads`
/// value (0 = auto).
pub fn deflate_into(
    data: &[u8],
    level: CompressionLevel,
    threads: usize,
    out: &mut Vec<u8>,
) -> DeflateStats {
    let params = level.params();
    let nchunks = chunk_count(data.len());
    let threads = effective_threads(threads, nchunks);
    let start_len = out.len();

    let per_thread = if threads <= 1 {
        deflate_serial(data, params, nchunks, out)
    } else {
        deflate_parallel(data, params, nchunks, threads, out)
    };

    DeflateStats {
        chunks: nchunks as u64,
        bytes_in: data.len() as u64,
        bytes_out: (out.len() - start_len) as u64,
        threads,
        per_thread,
    }
}

fn deflate_serial(
    data: &[u8],
    params: MatchParams,
    nchunks: usize,
    out: &mut Vec<u8>,
) -> Vec<u64> {
    let start_len = out.len();
    let mut w = BitWriter::new();
    let mut scratch = MatcherScratch::new();
    let mut tokens: Vec<Token> = Vec::new();
    for ci in 0..nchunks {
        let (cs, ce) = chunk_range(data.len(), ci);
        tokenize_chunk(data, cs, ce, params, &mut scratch, &mut tokens);
        emit_block(&mut w, &tokens, &data[cs..ce], (ci == nchunks - 1) as u32);
        w.drain_into(out);
    }
    w.finish_into(out);
    vec![(out.len() - start_len) as u64]
}

fn deflate_parallel(
    data: &[u8],
    params: MatchParams,
    nchunks: usize,
    threads: usize,
    out: &mut Vec<u8>,
) -> Vec<u64> {
    use std::sync::mpsc::sync_channel;

    let mut txs = Vec::with_capacity(threads);
    let mut rxs = Vec::with_capacity(threads);
    for _ in 0..threads {
        let (tx, rx) = sync_channel::<BitWriter>(CHANNEL_DEPTH);
        txs.push(tx);
        rxs.push(rx);
    }
    let mut per_thread = vec![0u64; threads];

    std::thread::scope(|s| {
        for (wi, tx) in txs.into_iter().enumerate() {
            s.spawn(move || {
                let mut scratch = MatcherScratch::new();
                let mut tokens: Vec<Token> = Vec::new();
                let mut ci = wi;
                while ci < nchunks {
                    let (cs, ce) = chunk_range(data.len(), ci);
                    tokenize_chunk(data, cs, ce, params, &mut scratch, &mut tokens);
                    let mut cw = BitWriter::new();
                    emit_block(&mut cw, &tokens, &data[cs..ce], (ci == nchunks - 1) as u32);
                    if tx.send(cw).is_err() {
                        return; // stitcher gone (panic unwinding)
                    }
                    ci += threads;
                }
            });
        }
        // Stitch chunks in order on the calling thread: chunk `ci` always
        // arrives on channel `ci % threads` in submission order, so no
        // reorder buffer is needed and the bounded channels cannot deadlock.
        let mut w = BitWriter::new();
        for ci in 0..nchunks {
            let cw = rxs[ci % threads]
                .recv()
                .expect("deflate worker terminated early");
            per_thread[ci % threads] += cw.bit_len().div_ceil(8) as u64;
            w.append(&cw);
            w.drain_into(out);
        }
        w.finish_into(out);
    });
    per_thread
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::compressible_bytes;
    use crate::util::rng::Pcg64;

    #[test]
    fn thread_resolution_clamps_to_chunks() {
        assert_eq!(effective_threads(4, 2), 2);
        assert_eq!(effective_threads(1, 10), 1);
        assert!(effective_threads(0, 64) >= 1);
    }

    #[test]
    fn parallel_output_is_bit_identical_to_serial() {
        let mut rng = Pcg64::seeded(104);
        for n in [0usize, 1000, 200_000, 300_000] {
            let data = compressible_bytes(&mut rng, n);
            let serial = deflate(&data, CompressionLevel::Default);
            for t in [2usize, 4, 8, 0] {
                let mut out = Vec::new();
                let stats = deflate_into(&data, CompressionLevel::Default, t, &mut out);
                assert_eq!(out, serial, "n={n} threads={t}");
                assert_eq!(stats.bytes_out as usize, out.len());
            }
        }
    }

    #[test]
    fn stats_account_for_the_stream() {
        let mut rng = Pcg64::seeded(105);
        let data = compressible_bytes(&mut rng, 300_000);
        let mut out = Vec::new();
        let stats = deflate_into(&data, CompressionLevel::Fast, 4, &mut out);
        assert_eq!(stats.chunks, 3);
        assert_eq!(stats.threads, 3); // clamped to chunk count
        assert_eq!(stats.bytes_in, 300_000);
        assert_eq!(stats.per_thread.len(), stats.threads);
        // Per-worker byte counts cover the stream up to the per-chunk
        // rounding (each chunk's contribution is counted in whole bytes).
        let accounted: u64 = stats.per_thread.iter().sum();
        assert!(accounted >= stats.bytes_out && accounted <= stats.bytes_out + stats.chunks);
    }

    #[test]
    fn appending_into_a_nonempty_buffer_preserves_the_prefix() {
        let mut rng = Pcg64::seeded(106);
        let data = compressible_bytes(&mut rng, 150_000);
        let mut out = vec![0xAA, 0xBB];
        let stats = deflate_into(&data, CompressionLevel::Default, 4, &mut out);
        assert_eq!(&out[..2], &[0xAA, 0xBB]);
        assert_eq!(stats.bytes_out as usize, out.len() - 2);
        assert_eq!(
            super::super::decoder::inflate(&out[2..]).unwrap(),
            data
        );
    }
}
