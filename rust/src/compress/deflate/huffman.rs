//! Huffman machinery for DEFLATE: LSB-first bit I/O, optimal
//! length-limited code construction (package-merge), canonical code
//! assignment (RFC 1951 §3.2.2) and a canonical decoder.

// ---------------------------------------------------------------------------
// Bit I/O (RFC 1951: bytes filled LSB-first; Huffman codes are emitted
// most-significant-code-bit first, i.e. bit-reversed before writing).
// ---------------------------------------------------------------------------

/// LSB-first bit writer.
#[derive(Default)]
pub struct BitWriter {
    pub bytes: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write `n` bits of `value` (LSB-first plain integer, used for extra
    /// bits and block headers).
    #[inline]
    pub fn write_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || (value as u64) < (1u64 << n));
        self.acc |= (value as u64) << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.bytes.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Write a Huffman code of `len` bits: DEFLATE stores codes
    /// most-significant-bit first, so reverse before the LSB-first write.
    #[inline]
    pub fn write_code(&mut self, code: u32, len: u32) {
        self.write_bits(reverse_bits(code, len), len);
    }

    /// Pad to a byte boundary with zero bits (for stored blocks).
    pub fn align_byte(&mut self) {
        if self.nbits > 0 {
            self.bytes.push(self.acc as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }

    /// Flush any partial byte and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.bytes
    }

    /// Current length in bits (for cost accounting).
    pub fn bit_len(&self) -> usize {
        self.bytes.len() * 8 + self.nbits as usize
    }

    /// Splice another writer's bit stream onto this one at the current bit
    /// offset (the parallel DEFLATE plane stitches per-chunk streams).
    ///
    /// Invariant relied on: a `BitWriter` never holds a full byte in `acc`
    /// (`write_bits` drains eagerly), so `other.nbits < 8` and the tail
    /// write below is a single partial byte.
    pub fn append(&mut self, other: &BitWriter) {
        if self.nbits == 0 {
            // Byte-aligned: bulk copy, then adopt the partial byte.
            self.bytes.extend_from_slice(&other.bytes);
            self.acc = other.acc;
            self.nbits = other.nbits;
            return;
        }
        for &b in &other.bytes {
            self.write_bits(b as u32, 8);
        }
        if other.nbits > 0 {
            self.write_bits(other.acc as u32, other.nbits);
        }
    }

    /// Move all completed bytes into `out`, keeping any partial byte
    /// buffered (streaming output: callers drain after each block).
    pub fn drain_into(&mut self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.bytes);
        self.bytes.clear();
    }

    /// Flush the final partial byte and move everything into `out`.
    pub fn finish_into(mut self, out: &mut Vec<u8>) {
        self.align_byte();
        out.append(&mut self.bytes);
    }
}

/// LSB-first bit reader.
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

/// Error kind shared with the decoder.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitsError(pub &'static str);

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self {
            data,
            pos: 0,
            acc: 0,
            nbits: 0,
        }
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.data.len() {
            self.acc |= (self.data[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read `n` bits as an LSB-first integer.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u32, BitsError> {
        debug_assert!(n <= 32);
        if self.nbits < n {
            self.refill();
            if self.nbits < n {
                return Err(BitsError("unexpected end of stream"));
            }
        }
        let v = (self.acc & ((1u64 << n) - 1)) as u32;
        self.acc >>= n;
        self.nbits -= n;
        Ok(v)
    }

    /// Peek up to 16 bits without consuming (zero-padded past the end).
    #[inline]
    pub fn peek16(&mut self) -> u32 {
        if self.nbits < 16 {
            self.refill();
        }
        (self.acc & 0xFFFF) as u32
    }

    /// Consume `n` bits previously peeked.
    #[inline]
    pub fn consume(&mut self, n: u32) -> Result<(), BitsError> {
        if self.nbits < n {
            return Err(BitsError("consume past end"));
        }
        self.acc >>= n;
        self.nbits -= n;
        Ok(())
    }

    /// Discard bits to the next byte boundary (stored blocks).
    pub fn align_byte(&mut self) {
        let drop = self.nbits % 8;
        self.acc >>= drop;
        self.nbits -= drop;
    }

    /// Read a whole little-endian u16 (after align_byte).
    pub fn read_u16(&mut self) -> Result<u16, BitsError> {
        Ok(self.read_bits(16)? as u16)
    }

    /// Copy `n` raw bytes (after align_byte).
    pub fn read_bytes(&mut self, n: usize, out: &mut Vec<u8>) -> Result<(), BitsError> {
        for _ in 0..n {
            out.push(self.read_bits(8)? as u8);
        }
        Ok(())
    }
}

#[inline]
pub fn reverse_bits(code: u32, len: u32) -> u32 {
    code.reverse_bits() >> (32 - len.max(1))
}

// ---------------------------------------------------------------------------
// Optimal length-limited code lengths: package-merge.
// ---------------------------------------------------------------------------

/// Compute optimal length-limited Huffman code lengths for `freqs`
/// (0-frequency symbols get length 0). `max_len` ≤ 15.
///
/// Uses the package-merge algorithm (Larmore & Hirschberg 1990): optimal
/// for the length constraint, O(max_len · n log n).
pub fn build_lengths(freqs: &[u32], max_len: u32) -> Vec<u8> {
    let n = freqs.len();
    let mut lengths = vec![0u8; n];
    let used: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    match used.len() {
        0 => return lengths,
        1 => {
            // RFC permits a single 1-bit code.
            lengths[used[0]] = 1;
            return lengths;
        }
        _ => {}
    }
    assert!(
        (1u64 << max_len) >= used.len() as u64,
        "alphabet of {} does not fit in {max_len}-bit codes",
        used.len()
    );

    // Each item is (weight, set-of-leaf-symbols). Sets are stored as count
    // increments applied on selection; we keep them as small Vec<u32> of
    // symbol ids (alphabets are ≤ 288, packages shallow — fine).
    #[derive(Clone)]
    struct Item {
        w: u64,
        leaves: Vec<u32>,
    }

    let mut leaves: Vec<Item> = used
        .iter()
        .map(|&i| Item {
            w: freqs[i] as u64,
            leaves: vec![i as u32],
        })
        .collect();
    leaves.sort_by_key(|it| it.w);

    // packages(l) for l = 1: just the leaves.
    let mut pkg: Vec<Item> = leaves.clone();
    for _ in 1..max_len {
        // Pair adjacent items into packages.
        let mut merged: Vec<Item> = Vec::with_capacity(pkg.len() / 2 + leaves.len());
        let mut pairs: Vec<Item> = Vec::with_capacity(pkg.len() / 2);
        let mut it = pkg.chunks_exact(2);
        for pair in &mut it {
            let mut leaves_combined =
                Vec::with_capacity(pair[0].leaves.len() + pair[1].leaves.len());
            leaves_combined.extend_from_slice(&pair[0].leaves);
            leaves_combined.extend_from_slice(&pair[1].leaves);
            pairs.push(Item {
                w: pair[0].w + pair[1].w,
                leaves: leaves_combined,
            });
        }
        // merge-sort leaves + pairs by weight.
        let (mut a, mut b) = (leaves.iter().peekable(), pairs.into_iter().peekable());
        loop {
            match (a.peek(), b.peek()) {
                (Some(x), Some(y)) => {
                    if x.w <= y.w {
                        merged.push((*a.next().unwrap()).clone());
                    } else {
                        merged.push(b.next().unwrap());
                    }
                }
                (Some(_), None) => merged.push((*a.next().unwrap()).clone()),
                (None, Some(_)) => merged.push(b.next().unwrap()),
                (None, None) => break,
            }
        }
        pkg = merged;
    }

    // Select the first 2(m-1) items; each selection of a leaf adds 1 to its
    // code length.
    let take = 2 * (used.len() - 1);
    for item in pkg.into_iter().take(take) {
        for s in item.leaves {
            lengths[s as usize] += 1;
        }
    }
    lengths
}

/// Assign canonical codes from code lengths (RFC 1951 §3.2.2). Returns
/// `codes[sym]` (valid where `lengths[sym] > 0`).
pub fn canonical_codes(lengths: &[u8]) -> Vec<u32> {
    let max_len = lengths.iter().copied().max().unwrap_or(0) as usize;
    let mut bl_count = vec![0u32; max_len + 1];
    for &l in lengths {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = vec![0u32; max_len + 2];
    let mut code = 0u32;
    for bits in 1..=max_len {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    let mut codes = vec![0u32; lengths.len()];
    for (sym, &l) in lengths.iter().enumerate() {
        if l > 0 {
            codes[sym] = next_code[l as usize];
            next_code[l as usize] += 1;
        }
    }
    codes
}

// ---------------------------------------------------------------------------
// Canonical decoder.
// ---------------------------------------------------------------------------

/// Fast table-driven canonical Huffman decoder.
///
/// A primary lookup table indexed by `PRIMARY_BITS` peeked bits resolves
/// short codes in one step; longer codes fall back to canonical
/// first-code/offset search.
pub struct Decoder {
    primary: Vec<(u16, u8)>, // (symbol, length) — length 0 = needs fallback
    // canonical fallback state
    counts: Vec<u32>,          // codes per length
    first_code: Vec<u32>,      // first canonical code of each length
    first_index: Vec<u32>,     // index into `sorted` of each length's run
    sorted: Vec<u16>,          // symbols ordered by (length, symbol)
    max_len: u32,
}

const PRIMARY_BITS: u32 = 9;

impl Decoder {
    /// Build from code lengths. Returns Err for over-subscribed /
    /// incomplete codes (except the degenerate 1-symbol code, which is
    /// allowed by zlib and produced by our encoder).
    pub fn new(lengths: &[u8]) -> Result<Decoder, BitsError> {
        let max_len = lengths.iter().copied().max().unwrap_or(0) as u32;
        if max_len == 0 {
            return Err(BitsError("empty huffman code"));
        }
        if max_len > 15 {
            return Err(BitsError("code length > 15"));
        }
        let mut counts = vec![0u32; (max_len + 1) as usize];
        for &l in lengths {
            if l > 0 {
                counts[l as usize] += 1;
            }
        }
        // Kraft check: allow incomplete codes only in the 1-symbol case.
        let mut left = 1i64;
        for bits in 1..=max_len {
            left = (left << 1) - counts[bits as usize] as i64;
            if left < 0 {
                return Err(BitsError("over-subscribed huffman code"));
            }
        }
        let nsyms: u32 = counts.iter().sum();
        if left > 0 && !(nsyms == 1 && max_len == 1) {
            return Err(BitsError("incomplete huffman code"));
        }

        let mut first_code = vec![0u32; (max_len + 2) as usize];
        let mut first_index = vec![0u32; (max_len + 2) as usize];
        let mut code = 0u32;
        let mut index = 0u32;
        for bits in 1..=max_len {
            first_code[bits as usize] = code;
            first_index[bits as usize] = index;
            code = (code + counts[bits as usize]) << 1;
            index += counts[bits as usize];
        }
        let mut sorted = vec![0u16; nsyms as usize];
        let mut next_idx = first_index.clone();
        for (sym, &l) in lengths.iter().enumerate() {
            if l > 0 {
                sorted[next_idx[l as usize] as usize] = sym as u16;
                next_idx[l as usize] += 1;
            }
        }

        // Primary table: for each PRIMARY_BITS-bit LSB-first peek value,
        // the decoded (symbol, length) if the code fits.
        let codes = canonical_codes(lengths);
        let table_len = 1usize << PRIMARY_BITS;
        let mut primary = vec![(0u16, 0u8); table_len];
        for (sym, &l) in lengths.iter().enumerate() {
            let l = l as u32;
            if l == 0 || l > PRIMARY_BITS {
                continue;
            }
            let rev = reverse_bits(codes[sym], l);
            // All peek values whose low `l` bits equal `rev` decode to sym.
            let step = 1usize << l;
            let mut v = rev as usize;
            while v < table_len {
                primary[v] = (sym as u16, l as u8);
                v += step;
            }
        }

        Ok(Decoder {
            primary,
            counts,
            first_code,
            first_index,
            sorted,
            max_len,
        })
    }

    /// Decode one symbol.
    #[inline]
    pub fn decode(&self, br: &mut BitReader) -> Result<u16, BitsError> {
        let peek = br.peek16();
        let (sym, len) = self.primary[(peek & ((1 << PRIMARY_BITS) - 1)) as usize];
        if len > 0 {
            br.consume(len as u32)?;
            return Ok(sym);
        }
        // Fallback: canonical search bit by bit (codes longer than
        // PRIMARY_BITS are rare).
        let mut code = 0u32;
        for bits in 1..=self.max_len {
            code = (code << 1) | ((peek >> (bits - 1)) & 1);
            if bits > 16 {
                return Err(BitsError("code too long"));
            }
            let c = self.counts[bits as usize];
            let fc = self.first_code[bits as usize];
            if c > 0 && code < fc + c && code >= fc {
                br.consume(bits)?;
                let idx = self.first_index[bits as usize] + (code - fc);
                return Ok(self.sorted[idx as usize]);
            }
        }
        Err(BitsError("invalid huffman code"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn bit_writer_reader_roundtrip() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xABCD, 16);
        w.write_bits(1, 1);
        w.write_bits(0x3FFFFFFF, 30);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert_eq!(r.read_bits(16).unwrap(), 0xABCD);
        assert_eq!(r.read_bits(1).unwrap(), 1);
        assert_eq!(r.read_bits(30).unwrap(), 0x3FFFFFFF);
        assert!(r.read_bits(8).is_err());
    }

    #[test]
    fn append_matches_single_writer_at_any_split() {
        let mut rng = Pcg64::seeded(95);
        let items: Vec<(u32, u32)> = (0..200)
            .map(|_| {
                let n = 1 + rng.below(24) as u32;
                (rng.below(1u64 << n) as u32, n)
            })
            .collect();
        let mut reference = BitWriter::new();
        for &(v, n) in &items {
            reference.write_bits(v, n);
        }
        let expect = reference.finish();
        for split in [0, 1, 37, 100, 199, 200] {
            let (mut a, mut b) = (BitWriter::new(), BitWriter::new());
            for &(v, n) in &items[..split] {
                a.write_bits(v, n);
            }
            for &(v, n) in &items[split..] {
                b.write_bits(v, n);
            }
            a.append(&b);
            assert_eq!(a.finish(), expect, "split {split}");
        }
    }

    #[test]
    fn drain_into_preserves_the_stream() {
        let mut w = BitWriter::new();
        let mut out = Vec::new();
        w.write_bits(0b10110, 5);
        w.write_bits(0xF0F0, 16);
        w.drain_into(&mut out); // partial byte stays buffered
        assert_eq!(out.len(), 2);
        w.write_bits(0b111, 3);
        w.finish_into(&mut out);
        let mut reference = BitWriter::new();
        reference.write_bits(0b10110, 5);
        reference.write_bits(0xF0F0, 16);
        reference.write_bits(0b111, 3);
        assert_eq!(out, reference.finish());
    }

    #[test]
    fn reverse_bits_examples() {
        assert_eq!(reverse_bits(0b110, 3), 0b011);
        assert_eq!(reverse_bits(0b1, 1), 0b1);
        assert_eq!(reverse_bits(0b10000000, 8), 0b00000001);
    }

    #[test]
    fn package_merge_matches_huffman_when_unconstrained() {
        // freqs 1,1,2,4: optimal lengths 3,3,2,1 (cost 1*3+1*3+2*2+4*1 = 14).
        let lens = build_lengths(&[1, 1, 2, 4], 15);
        let cost: u64 = lens
            .iter()
            .zip(&[1u32, 1, 2, 4])
            .map(|(&l, &f)| l as u64 * f as u64)
            .sum();
        assert_eq!(cost, 14);
        assert!(kraft_ok(&lens));
    }

    #[test]
    fn package_merge_respects_length_limit() {
        // Exponential freqs would want a deep tree; limit to 4.
        let freqs: Vec<u32> = (0..12).map(|i| 1 << i).collect();
        let lens = build_lengths(&freqs, 4);
        assert!(lens.iter().all(|&l| l <= 4 && l > 0));
        assert!(kraft_ok(&lens));
    }

    #[test]
    fn single_symbol_code() {
        let lens = build_lengths(&[0, 7, 0], 15);
        assert_eq!(lens, vec![0, 1, 0]);
        let dec = Decoder::new(&lens).unwrap();
        let mut w = BitWriter::new();
        let codes = canonical_codes(&lens);
        w.write_code(codes[1], 1);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(dec.decode(&mut r).unwrap(), 1);
    }

    fn kraft_ok(lens: &[u8]) -> bool {
        let sum: f64 = lens
            .iter()
            .filter(|&&l| l > 0)
            .map(|&l| 2f64.powi(-(l as i32)))
            .sum();
        sum <= 1.0 + 1e-12
    }

    #[test]
    fn encode_decode_random_alphabets() {
        let mut rng = Pcg64::seeded(81);
        for trial in 0..20 {
            let n = 2 + rng.below_usize(280);
            let freqs: Vec<u32> = (0..n)
                .map(|_| if rng.bernoulli(0.3) { 0 } else { 1 + rng.below(1000) as u32 })
                .collect();
            if freqs.iter().filter(|&&f| f > 0).count() < 2 {
                continue;
            }
            let lens = build_lengths(&freqs, 15);
            assert!(kraft_ok(&lens), "trial {trial}");
            let codes = canonical_codes(&lens);
            let dec = Decoder::new(&lens).unwrap();
            // Encode a random symbol sequence and decode it back.
            let syms: Vec<u16> = (0..200)
                .map(|_| loop {
                    let s = rng.below_usize(n);
                    if freqs[s] > 0 {
                        return s as u16;
                    }
                })
                .collect();
            let mut w = BitWriter::new();
            for &s in &syms {
                w.write_code(codes[s as usize], lens[s as usize] as u32);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &s in &syms {
                assert_eq!(dec.decode(&mut r).unwrap(), s, "trial {trial}");
            }
        }
    }

    #[test]
    fn decoder_rejects_oversubscribed() {
        // Three codes of length 1 is over-subscribed.
        assert!(Decoder::new(&[1, 1, 1]).is_err());
        // Incomplete: a single length-2 code (a lone symbol must be coded
        // with 1 bit) — rejected.
        assert!(Decoder::new(&[2, 0, 0]).is_err());
        // The legal degenerate: one symbol at length 1.
        assert!(Decoder::new(&[0, 1, 0]).is_ok());
    }

    #[test]
    fn long_codes_fall_back_past_primary_table() {
        // Construct lengths with a code longer than PRIMARY_BITS.
        let mut freqs = vec![0u32; 40];
        for (i, f) in freqs.iter_mut().enumerate() {
            *f = 1 + (i as u32 % 3); // flat-ish -> lengths ~6
        }
        freqs[0] = 1 << 20; // force a very short code for 0, long for others
        let lens = build_lengths(&freqs, 15);
        let codes = canonical_codes(&lens);
        let dec = Decoder::new(&lens).unwrap();
        let mut w = BitWriter::new();
        for s in 0..40u16 {
            w.write_code(codes[s as usize], lens[s as usize] as u32);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for s in 0..40u16 {
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }
}
