//! DEFLATE block writer: per block chooses stored / fixed-Huffman /
//! dynamic-Huffman by exact bit cost (RFC 1951 §3.2) and emits it into a
//! `BitWriter`. The caller (serial loop or a parallel worker) owns block
//! boundaries and the BFINAL bit; this module is pure per-block emission,
//! so chunk workers can run it concurrently on disjoint token slices.

use super::huffman::{build_lengths, canonical_codes, BitWriter};
use super::lz77::Token;

// ---- RFC 1951 length / distance code tables -------------------------------

/// `(base, extra_bits)` for length codes 257..=285.
pub const LENGTH_TABLE: [(u16, u8); 29] = [
    (3, 0), (4, 0), (5, 0), (6, 0), (7, 0), (8, 0), (9, 0), (10, 0),
    (11, 1), (13, 1), (15, 1), (17, 1), (19, 2), (23, 2), (27, 2), (31, 2),
    (35, 3), (43, 3), (51, 3), (59, 3), (67, 4), (83, 4), (99, 4), (115, 4),
    (131, 5), (163, 5), (195, 5), (227, 5), (258, 0),
];

/// `(base, extra_bits)` for distance codes 0..=29.
pub const DIST_TABLE: [(u16, u8); 30] = [
    (1, 0), (2, 0), (3, 0), (4, 0), (5, 1), (7, 1), (9, 2), (13, 2),
    (17, 3), (25, 3), (33, 4), (49, 4), (65, 5), (97, 5), (129, 6), (193, 6),
    (257, 7), (385, 7), (513, 8), (769, 8), (1025, 9), (1537, 9),
    (2049, 10), (3073, 10), (4097, 11), (6145, 11), (8193, 12), (12289, 12),
    (16385, 13), (24577, 13),
];

/// Order in which code-length-code lengths are transmitted (§3.2.7).
pub const CLEN_ORDER: [usize; 19] = [
    16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15,
];

/// Map a match length (3..=258) to `(code_index, extra_bits, extra_val)`.
#[inline]
pub fn length_code(len: u16) -> (usize, u8, u16) {
    debug_assert!((3..=258).contains(&len));
    // Binary search is overkill for 29 entries; linear from a coarse guess.
    let mut idx = LENGTH_TABLE.len() - 1;
    for (i, &(base, _)) in LENGTH_TABLE.iter().enumerate() {
        if base > len {
            idx = i - 1;
            break;
        }
    }
    if LENGTH_TABLE[LENGTH_TABLE.len() - 1].0 <= len {
        idx = LENGTH_TABLE.len() - 1;
    }
    let (base, extra) = LENGTH_TABLE[idx];
    (idx, extra, len - base)
}

/// Map a distance (1..=32768) to `(code_index, extra_bits, extra_val)`.
#[inline]
pub fn dist_code(dist: u16) -> (usize, u8, u16) {
    debug_assert!(dist >= 1);
    let mut idx = DIST_TABLE.len() - 1;
    for (i, &(base, _)) in DIST_TABLE.iter().enumerate() {
        if base > dist {
            idx = i - 1;
            break;
        }
    }
    if DIST_TABLE[DIST_TABLE.len() - 1].0 <= dist {
        idx = DIST_TABLE.len() - 1;
    }
    let (base, extra) = DIST_TABLE[idx];
    (idx, extra, dist - base)
}

/// Fixed lit/len code lengths (§3.2.6).
pub fn fixed_lit_lengths() -> [u8; 288] {
    let mut l = [8u8; 288];
    for x in l.iter_mut().take(256).skip(144) {
        *x = 9;
    }
    for x in l.iter_mut().take(280).skip(256) {
        *x = 7;
    }
    l
}

/// Fixed distance code lengths: 5 bits for all 32 codes (30 real distance
/// codes + 2 reserved — included so the code is complete, per §3.2.6).
pub fn fixed_dist_lengths() -> [u8; 32] {
    [5u8; 32]
}

const END_OF_BLOCK: usize = 256;
const MAX_STORED: usize = 65535;

/// Frequencies of the lit/len and distance alphabets for a token slice.
fn frequencies(tokens: &[Token]) -> ([u32; 286], [u32; 30]) {
    let mut lit = [0u32; 286];
    let mut dist = [0u32; 30];
    for t in tokens {
        match *t {
            Token::Literal(b) => lit[b as usize] += 1,
            Token::Match { len, dist: d } => {
                lit[257 + length_code(len).0] += 1;
                dist[dist_code(d).0] += 1;
            }
        }
    }
    lit[END_OF_BLOCK] += 1;
    (lit, dist)
}

/// Bit cost of the token payload under the given code lengths.
fn payload_cost(tokens: &[Token], lit_len: &[u8], dist_len: &[u8]) -> usize {
    let mut bits = lit_len[END_OF_BLOCK] as usize;
    for t in tokens {
        match *t {
            Token::Literal(b) => bits += lit_len[b as usize] as usize,
            Token::Match { len, dist: d } => {
                let (lc, le, _) = length_code(len);
                let (dc, de, _) = dist_code(d);
                bits += lit_len[257 + lc] as usize
                    + le as usize
                    + dist_len[dc] as usize
                    + de as usize;
            }
        }
    }
    bits
}

/// RLE-encode code lengths with symbols 0..=18 (§3.2.7). Returns
/// `(symbol, extra_bits_value)` pairs.
fn rle_code_lengths(lengths: &[u8]) -> Vec<(u8, u8)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < lengths.len() {
        let v = lengths[i];
        let mut run = 1usize;
        while i + run < lengths.len() && lengths[i + run] == v {
            run += 1;
        }
        if v == 0 {
            let mut left = run;
            while left >= 11 {
                let take = left.min(138);
                out.push((18, (take - 11) as u8));
                left -= take;
            }
            if left >= 3 {
                out.push((17, (left - 3) as u8));
                left = 0;
            }
            for _ in 0..left {
                out.push((0, 0));
            }
        } else {
            out.push((v, 0));
            let mut left = run - 1;
            while left >= 3 {
                let take = left.min(6);
                out.push((16, (take - 3) as u8));
                left -= take;
            }
            for _ in 0..left {
                out.push((v, 0));
            }
        }
        i += run;
    }
    out
}

struct DynamicPlan {
    lit_len: Vec<u8>,
    dist_len: Vec<u8>,
    clen_len: Vec<u8>,
    rle: Vec<(u8, u8)>,
    hlit: usize,
    hdist: usize,
    hclen: usize,
    header_bits: usize,
}

fn plan_dynamic(tokens: &[Token]) -> DynamicPlan {
    let (lit_freq, dist_freq) = frequencies(tokens);
    let mut lit_len = build_lengths(&lit_freq, 15);
    let mut dist_len = build_lengths(&dist_freq, 15);
    // At least one distance code must be describable; if no matches, give
    // distance symbol 0 a 1-bit code (a legal single-symbol code).
    if dist_len.iter().all(|&l| l == 0) {
        dist_len[0] = 1;
    }
    // HLIT/HDIST: trailing zero lengths may be trimmed (minimums 257 / 1).
    let hlit = lit_len
        .iter()
        .rposition(|&l| l > 0)
        .map(|p| p + 1)
        .unwrap_or(257)
        .max(257);
    let hdist = dist_len
        .iter()
        .rposition(|&l| l > 0)
        .map(|p| p + 1)
        .unwrap_or(1)
        .max(1);
    lit_len.truncate(hlit);
    dist_len.truncate(hdist);

    // RLE over the concatenated length arrays.
    let mut all: Vec<u8> = Vec::with_capacity(hlit + hdist);
    all.extend_from_slice(&lit_len);
    all.extend_from_slice(&dist_len);
    let rle = rle_code_lengths(&all);

    let mut clen_freq = [0u32; 19];
    for &(s, _) in &rle {
        clen_freq[s as usize] += 1;
    }
    let clen_len = build_lengths(&clen_freq, 7);
    let hclen = (4..=19)
        .rev()
        .find(|&k| clen_len[CLEN_ORDER[k - 1]] > 0)
        .unwrap_or(4)
        .max(4);

    let mut header_bits = 5 + 5 + 4 + hclen * 3;
    for &(s, _) in &rle {
        header_bits += clen_len[s as usize] as usize
            + match s {
                16 => 2,
                17 => 3,
                18 => 7,
                _ => 0,
            };
    }

    DynamicPlan {
        lit_len,
        dist_len,
        clen_len,
        rle,
        hlit,
        hdist,
        hclen,
        header_bits,
    }
}

/// Emit one DEFLATE block for `tokens` covering the raw bytes `raw`, with
/// the given BFINAL bit. Picks stored / fixed / dynamic by exact bit cost.
pub fn emit_block(w: &mut BitWriter, tokens: &[Token], raw: &[u8], final_bit: u32) {
    let fixed_lit = fixed_lit_lengths();
    let fixed_dist = fixed_dist_lengths();
    let cost_fixed = 3 + payload_cost(tokens, &fixed_lit, &fixed_dist);

    let plan = plan_dynamic(tokens);
    let cost_dynamic =
        3 + plan.header_bits + payload_cost(tokens, &plan.lit_len, &plan.dist_len);

    // Stored cost: 3 bits + pad to byte + (LEN/NLEN + bytes) per ≤64 KiB chunk.
    let nchunks = raw.len().div_ceil(MAX_STORED).max(1);
    let cost_stored_bytes = nchunks * 5 + raw.len();
    let cost_stored = cost_stored_bytes * 8 + 7; // worst-case alignment

    if cost_stored < cost_fixed.min(cost_dynamic) {
        emit_stored(w, raw, final_bit);
    } else if cost_fixed <= cost_dynamic {
        w.write_bits(final_bit, 1);
        w.write_bits(0b01, 2); // fixed
        emit_tokens(w, tokens, &fixed_lit, &fixed_dist);
    } else {
        w.write_bits(final_bit, 1);
        w.write_bits(0b10, 2); // dynamic
        emit_dynamic_header(w, &plan);
        emit_tokens(w, tokens, &plan.lit_len, &plan.dist_len);
    }
}

fn emit_stored(w: &mut BitWriter, raw: &[u8], final_bit: u32) {
    // At least one (possibly empty) stored chunk, ≤64 KiB each.
    let nchunks = raw.len().div_ceil(MAX_STORED).max(1);
    for i in 0..nchunks {
        let chunk = &raw[i * MAX_STORED..raw.len().min((i + 1) * MAX_STORED)];
        let f = if i == nchunks - 1 { final_bit } else { 0 };
        w.write_bits(f, 1);
        w.write_bits(0b00, 2); // stored
        w.align_byte();
        let len = chunk.len() as u16;
        w.write_bits(len as u32, 16);
        w.write_bits(!len as u32, 16);
        for &b in chunk {
            w.write_bits(b as u32, 8);
        }
    }
}

fn emit_dynamic_header(w: &mut BitWriter, plan: &DynamicPlan) {
    w.write_bits((plan.hlit - 257) as u32, 5);
    w.write_bits((plan.hdist - 1) as u32, 5);
    w.write_bits((plan.hclen - 4) as u32, 4);
    for &ord in CLEN_ORDER.iter().take(plan.hclen) {
        w.write_bits(plan.clen_len[ord] as u32, 3);
    }
    let clen_codes = canonical_codes(&plan.clen_len);
    for &(s, extra) in &plan.rle {
        w.write_code(clen_codes[s as usize], plan.clen_len[s as usize] as u32);
        match s {
            16 => w.write_bits(extra as u32, 2),
            17 => w.write_bits(extra as u32, 3),
            18 => w.write_bits(extra as u32, 7),
            _ => {}
        }
    }
}

fn emit_tokens(w: &mut BitWriter, tokens: &[Token], lit_len: &[u8], dist_len: &[u8]) {
    let lit_codes = canonical_codes(lit_len);
    let dist_codes = canonical_codes(dist_len);
    for t in tokens {
        match *t {
            Token::Literal(b) => {
                w.write_code(lit_codes[b as usize], lit_len[b as usize] as u32)
            }
            Token::Match { len, dist } => {
                let (lc, le, lv) = length_code(len);
                w.write_code(lit_codes[257 + lc], lit_len[257 + lc] as u32);
                if le > 0 {
                    w.write_bits(lv as u32, le as u32);
                }
                let (dc, de, dv) = dist_code(dist);
                w.write_code(dist_codes[dc], dist_len[dc] as u32);
                if de > 0 {
                    w.write_bits(dv as u32, de as u32);
                }
            }
        }
    }
    w.write_code(
        lit_codes[END_OF_BLOCK],
        lit_len[END_OF_BLOCK] as u32,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn length_code_boundaries() {
        assert_eq!(length_code(3), (0, 0, 0));
        assert_eq!(length_code(10), (7, 0, 0));
        assert_eq!(length_code(11), (8, 1, 0));
        assert_eq!(length_code(12), (8, 1, 1));
        assert_eq!(length_code(257), (27, 5, 30));
        assert_eq!(length_code(258), (28, 0, 0));
    }

    #[test]
    fn dist_code_boundaries() {
        assert_eq!(dist_code(1), (0, 0, 0));
        assert_eq!(dist_code(4), (3, 0, 0));
        assert_eq!(dist_code(5), (4, 1, 0));
        assert_eq!(dist_code(6), (4, 1, 1));
        assert_eq!(dist_code(24577), (29, 13, 0));
        assert_eq!(dist_code(32768), (29, 13, 8191));
    }

    #[test]
    fn rle_examples() {
        // 5 zeros -> one 17 with extra 2 (5-3).
        assert_eq!(rle_code_lengths(&[0, 0, 0, 0, 0]), vec![(17, 2)]);
        // value run: v + 16-repeats.
        assert_eq!(
            rle_code_lengths(&[7, 7, 7, 7, 7]),
            vec![(7, 0), (16, 1)] // 7 then repeat 4 times (3 + extra 1)
        );
        // short runs stay literal.
        assert_eq!(rle_code_lengths(&[3, 3]), vec![(3, 0), (3, 0)]);
        // long zero run uses 18.
        assert_eq!(rle_code_lengths(&[0; 140]), vec![(18, 127), (0, 0), (0, 0)]);
    }

    #[test]
    fn fixed_tables_shape() {
        let l = fixed_lit_lengths();
        assert_eq!(l[0], 8);
        assert_eq!(l[143], 8);
        assert_eq!(l[144], 9);
        assert_eq!(l[255], 9);
        assert_eq!(l[256], 7);
        assert_eq!(l[279], 7);
        assert_eq!(l[280], 8);
        assert_eq!(l[287], 8);
    }

    #[test]
    fn empty_token_block_is_a_valid_final_block() {
        let mut w = BitWriter::new();
        emit_block(&mut w, &[], &[], 1);
        let bytes = w.finish();
        assert_eq!(super::super::decoder::inflate(&bytes).unwrap(), Vec::<u8>::new());
    }
}
