//! LZ77 match finder for DEFLATE: 32 KiB sliding window, 3-byte hash
//! chains, optional lazy matching (evaluate the match starting at the next
//! byte before committing, as zlib does).

pub const WINDOW_SIZE: usize = 32 * 1024;
pub const MIN_MATCH: usize = 3;
pub const MAX_MATCH: usize = 258;

/// One token of the LZ77 stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Token {
    Literal(u8),
    /// Back-reference: `len` in 3..=258, `dist` in 1..=32768.
    Match { len: u16, dist: u16 },
}

/// Matcher effort knobs (indexed by compression level).
#[derive(Debug, Clone, Copy)]
pub struct MatchParams {
    /// Maximum chain positions examined per match attempt.
    pub max_chain: usize,
    /// Stop early once a match of this length is found.
    pub good_len: usize,
    /// Enable lazy matching.
    pub lazy: bool,
}

impl MatchParams {
    pub fn fast() -> Self {
        MatchParams {
            max_chain: 8,
            good_len: 32,
            lazy: false,
        }
    }
    pub fn default_level() -> Self {
        // Perf-tuned (EXPERIMENTS.md §Perf): greedy with a short chain.
        // On quantized-gradient payloads lazy matching bought <2% ratio
        // for ~2.5x the encode time.
        MatchParams {
            max_chain: 32,
            good_len: 64,
            lazy: false,
        }
    }
    pub fn best() -> Self {
        MatchParams {
            max_chain: 512,
            good_len: MAX_MATCH,
            lazy: true,
        }
    }
}

/// Cap on hash-chain insertions per committed match (perf; long runs
/// would otherwise insert hundreds of identical positions).
pub(super) const MAX_INSERTS: usize = 32;

const HASH_BITS: u32 = 15;
pub(super) const HASH_SIZE: usize = 1 << HASH_BITS;
pub(super) const NIL: u32 = u32::MAX;

/// Length of the common prefix of `data[a..]` and `data[b..]`, capped at
/// `max_len`. Compares 8 bytes at a time (perf: this is the hottest loop
/// of the DEFLATE encoder — see EXPERIMENTS.md §Perf).
#[inline]
pub(super) fn match_len(data: &[u8], a: usize, b: usize, max_len: usize) -> usize {
    let mut l = 0usize;
    while l + 8 <= max_len && b + l + 8 <= data.len() {
        let x = u64::from_le_bytes(data[a + l..a + l + 8].try_into().unwrap());
        let y = u64::from_le_bytes(data[b + l..b + l + 8].try_into().unwrap());
        let diff = x ^ y;
        if diff != 0 {
            return l + (diff.trailing_zeros() / 8) as usize;
        }
        l += 8;
    }
    while l < max_len && data[a + l] == data[b + l] {
        l += 1;
    }
    l
}

#[inline]
pub(super) fn hash3(data: &[u8], i: usize) -> usize {
    // Multiplicative hash of 3 bytes.
    let v = (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16);
    ((v.wrapping_mul(0x9E37_79B1)) >> (32 - HASH_BITS)) as usize
}

/// Tokenize `data` into literals and back-references.
pub fn tokenize(data: &[u8], params: MatchParams) -> Vec<Token> {
    let n = data.len();
    let mut tokens = Vec::with_capacity(n / 3 + 16);
    if n < MIN_MATCH + 1 {
        tokens.extend(data.iter().map(|&b| Token::Literal(b)));
        return tokens;
    }

    let mut head = vec![NIL; HASH_SIZE];
    let mut prev = vec![NIL; n];

    #[inline]
    fn find_match(
        data: &[u8],
        pos: usize,
        head: &[u32],
        prev: &[u32],
        params: &MatchParams,
    ) -> (usize, usize) {
        let n = data.len();
        if pos + MIN_MATCH > n {
            return (0, 0);
        }
        let max_len = MAX_MATCH.min(n - pos);
        let mut best_len = MIN_MATCH - 1;
        let mut best_dist = 0usize;
        let mut cand = head[hash3(data, pos)];
        let min_pos = pos.saturating_sub(WINDOW_SIZE);
        let mut chain = params.max_chain;
        while cand != NIL && (cand as usize) >= min_pos && chain > 0 {
            let c = cand as usize;
            if c >= pos {
                break;
            }
            // Quick reject: check the byte past the current best.
            if best_len < max_len && data[c + best_len] == data[pos + best_len] {
                let l = match_len(data, c, pos, max_len);
                if l > best_len {
                    best_len = l;
                    best_dist = pos - c;
                    if l >= params.good_len {
                        break;
                    }
                }
            }
            cand = prev[c];
            chain -= 1;
        }
        if best_len >= MIN_MATCH {
            (best_len, best_dist)
        } else {
            (0, 0)
        }
    }

    #[inline]
    fn insert(data: &[u8], pos: usize, head: &mut [u32], prev: &mut [u32]) {
        if pos + MIN_MATCH <= data.len() {
            let h = hash3(data, pos);
            prev[pos] = head[h];
            head[h] = pos as u32;
        }
    }

    let mut i = 0usize;
    while i < n {
        let (len, dist) = find_match(data, i, &head, &prev, &params);
        if len == 0 {
            insert(data, i, &mut head, &mut prev);
            tokens.push(Token::Literal(data[i]));
            i += 1;
            continue;
        }

        // Lazy matching: if the match starting at i+1 is strictly longer,
        // emit data[i] as a literal and defer.
        if params.lazy && len < params.good_len && i + 1 < n {
            insert(data, i, &mut head, &mut prev);
            let (len2, _d2) = find_match(data, i + 1, &head, &prev, &params);
            if len2 > len {
                tokens.push(Token::Literal(data[i]));
                i += 1;
                continue;
            }
            // Commit to the match at i; insert its covered positions.
            tokens.push(Token::Match {
                len: len as u16,
                dist: dist as u16,
            });
            // Perf: for very long matches (runs), inserting every covered
            // position costs more than it gains — cap insertions (zlib's
            // fast-level heuristic). See EXPERIMENTS.md §Perf.
            let ins_end = (i + len).min(n).min(i + 1 + MAX_INSERTS);
            for p in i + 1..ins_end {
                insert(data, p, &mut head, &mut prev);
            }
            i += len;
        } else {
            tokens.push(Token::Match {
                len: len as u16,
                dist: dist as u16,
            });
            let ins_end = (i + len).min(n).min(i + MAX_INSERTS);
            for p in i..ins_end {
                insert(data, p, &mut head, &mut prev);
            }
            i += len;
        }
    }
    tokens
}

/// Expand tokens back to bytes (used by tests and the decoder's contract).
pub fn expand(tokens: &[Token]) -> Vec<u8> {
    let mut out = Vec::new();
    for t in tokens {
        match *t {
            Token::Literal(b) => out.push(b),
            Token::Match { len, dist } => {
                let start = out
                    .len()
                    .checked_sub(dist as usize)
                    .expect("match distance beyond output");
                for k in 0..len as usize {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{bytes, compressible_bytes, forall};

    #[test]
    fn literal_only_for_tiny_input() {
        let t = tokenize(b"ab", MatchParams::default_level());
        assert_eq!(t, vec![Token::Literal(b'a'), Token::Literal(b'b')]);
    }

    #[test]
    fn detects_runs() {
        let data = vec![5u8; 300];
        let t = tokenize(&data, MatchParams::default_level());
        // One literal then matches with dist 1 covering the run.
        assert_eq!(t[0], Token::Literal(5));
        assert!(matches!(t[1], Token::Match { dist: 1, .. }));
        assert!(t.len() <= 4, "run should compress to few tokens: {t:?}");
        assert_eq!(expand(&t), data);
    }

    #[test]
    fn detects_repeated_motif() {
        let motif = b"hello world, ";
        let mut data = Vec::new();
        for _ in 0..50 {
            data.extend_from_slice(motif);
        }
        let t = tokenize(&data, MatchParams::default_level());
        let matches = t.iter().filter(|t| matches!(t, Token::Match { .. })).count();
        assert!(matches >= 2, "{t:?}");
        assert_eq!(expand(&t), data);
    }

    #[test]
    fn match_lengths_and_distances_in_range() {
        forall(
            30,
            91,
            |rng, size| { let n = size.len(rng) * 211; compressible_bytes(rng, n) },
            |data| {
                let t = tokenize(data, MatchParams::default_level());
                t.iter().all(|tok| match *tok {
                    Token::Literal(_) => true,
                    Token::Match { len, dist } => {
                        (MIN_MATCH..=MAX_MATCH).contains(&(len as usize))
                            && (1..=WINDOW_SIZE).contains(&(dist as usize))
                    }
                }) && expand(&t) == *data
            },
        );
    }

    #[test]
    fn expand_inverts_tokenize_on_random_data() {
        forall(
            30,
            92,
            |rng, size| { let n = size.len(rng) * 97; bytes(rng, n) },
            |data| expand(&tokenize(data, MatchParams::fast())) == *data,
        );
    }

    #[test]
    fn all_param_levels_roundtrip() {
        let mut rng = crate::util::rng::Pcg64::seeded(93);
        let data = compressible_bytes(&mut rng, 40_000);
        for p in [MatchParams::fast(), MatchParams::default_level(), MatchParams::best()] {
            assert_eq!(expand(&tokenize(&data, p)), data);
        }
    }

    #[test]
    fn lazy_matching_not_worse_than_greedy() {
        let mut rng = crate::util::rng::Pcg64::seeded(94);
        let data = compressible_bytes(&mut rng, 60_000);
        let greedy = tokenize(&data, MatchParams { lazy: false, ..MatchParams::default_level() });
        let lazy = tokenize(&data, MatchParams::default_level());
        assert!(lazy.len() <= greedy.len() + greedy.len() / 20);
    }
}
