//! Tensor compression stack.
//!
//! The paper's contribution ([`cosine`]) plus every baseline it compares
//! against, composed by a direction-agnostic stage [`pipeline`], the
//! lossless stage ([`deflate`], built from scratch), and the byte-exact
//! [`wire`] format (`CSG2`) the simulated network meters.
//!
//! The same pipeline runs both arrows of Algorithm 1:
//!
//! ```text
//!              uplink: g = M_in − M*        downlink: Δ = M^{t+1} − M^t
//!                         │                              │
//!                         ▼                              ▼
//!   ┌─────────────────────────────────────────────────────────────────┐
//!   │ Pipeline stages              (buffers live in an EncodeScratch  │
//!   │                               arena — zero stage allocations    │
//!   │                               in the steady state)              │
//!   │   EF fold      p = v + residual      (optional, endpoint-local) │
//!   │   sparsify     seeded random mask    (keep_frac < 1)            │
//!   │   rotate       Hadamard ±1 rotation  (optional, any quantizer)  │
//!   │   quantize     impl Quantizer        (cosine / linear / sign /  │
//!   │                │                      float32 passthrough)      │
//!   │                └─ kernel fast path:  biased cosine encodes by   │
//!   │                   threshold search, decodes by 2^s-entry LUT —  │
//!   │                   zero transcendentals per element, bit-exact   │
//!   │                   vs the reference acos/cos path                │
//!   │   bit-pack     s bits per code       (64-bit word-at-a-time;    │
//!   │                                       skipped at 32 bits)       │
//!   │   DEFLATE      lossless (§4)         (kept only if smaller)     │
//!   │                │  pipelined plane: 128 KiB chunks fan out to    │
//!   │                │  N match-finder workers, one block per chunk,  │
//!   │                │  bit-stitched in order — bytes identical at    │
//!   │                │  ANY thread count (`--deflate-threads`, 0 =    │
//!   │                │  auto; `--deflate-level fast|default|best`)    │
//!   └─────────────────────────────────────────────────────────────────┘
//!                         │
//!                         ▼
//!        EncodedTensor ──wire::serialize──► CSG2 frame (44 B header)
//!          (or fused: `encode_wire_with` streams compressed chunks
//!           straight into the frame buffer behind the header, so
//!           serialization overlaps compression)
//!                         │
//!                         ├──▶ fl::NetworkLedger   (bytes moved)
//!                         └──▶ sim::FleetSim       (bytes ÷ device
//!                              bandwidth = simulated transfer time)
//! ```
//!
//! The receiver inverts every stage from the self-describing header via
//! [`pipeline::decode`] — no sender configuration needed. Decoded uplink
//! gradients feed FedAvg aggregation (Eq. 1); decoded downlink deltas
//! advance the clients' model replica. The *size* of every frame feeds
//! two meters: the byte-exact [`crate::fl::NetworkLedger`], and — when
//! the systems simulator is on — the virtual clock of [`crate::sim`],
//! which turns compression ratios into time-to-accuracy speedups.
//!
//! The *measured* size also feeds back: the server folds each accepted
//! frame's as-traveled bytes (header + post-DEFLATE payload) into its
//! round observations, and the [`allocator`]'s bit controller learns a
//! per-layer cost scale (EWMA) from them, so adaptive water-filling
//! budgets against what segments actually cost after lossless
//! compression instead of the analytic pre-DEFLATE estimate.
//!
//! ## Fast kernels ([`kernel`])
//!
//! The hot loop never calls a transcendental: the biased cosine encode
//! collapses into a per-tensor table of `2^s − 1` value-domain thresholds
//! (the angle bin edges pushed through the monotone `cos`, then pinned to
//! the exact f32 cutover of the reference map by bit-level bisection) and
//! a branchless binary search per element; decode indexes a `2^s`-entry
//! level LUT. Both are **bit-identical** to the reference `acos`/`cos`
//! path — property-tested across all bit widths in
//! `tests/kernel_equivalence.rs` — so the fast path is simply *the* path;
//! the reference survives as `quantize_reference` for `Rounding::Unbiased`
//! (whose stochastic rounding is not a pure function of the input) and as
//! the tests' ground truth.
//!
//! Adding a scheme = one `impl Quantizer` + one [`quantizer::from_wire`]
//! arm; the pipeline, wire format, figures and cost ledgers pick it up
//! unchanged.
//!
//! ## Machine-enforced invariants (`repro analyze`)
//!
//! Two properties of this stack are linted by the in-tree analyzer
//! ([`crate::analyze`], CI-gated) rather than trusted to review:
//! *hot-path purity* — no transcendentals and no `.clone()`/`.to_vec()`
//! in [`kernel`]/[`bitpack`] or the DEFLATE per-chunk loops
//! (`deflate/matcher.rs`, `deflate/block.rs`) outside explicitly waived
//! reference paths (the LUT/threshold builders, the `acos` ground truth,
//! one-time scratch construction) — and *wire
//! invariants* — [`wire`] is the single definition site of
//! `HEADER_BYTES` and the `CSG2` magic, its header layout doc table must
//! sum to `HEADER_BYTES`, and no other module may hardcode either.
//! Scopes and waivers live in `rust/analyze.toml`.

pub mod allocator;
pub mod bitpack;
pub mod cosine;
pub mod deflate;
pub mod entropy;
pub mod hadamard;
pub mod kernel;
pub mod linear;
pub mod perf;
pub mod pipeline;
pub mod quantizer;
pub mod signsgd;
pub mod sparsify;
pub mod topk;
pub mod wire;

pub use allocator::{BitController, BitPlan, BitSchedule, LayerMap, SegmentObs};
pub use kernel::KernelScratch;
pub use pipeline::{
    accumulate_range_with, accumulate_with, decode, decode_with, Direction, EncodeScratch,
    EncodedTensor, Pipeline, PipelineState,
};
pub use quantizer::{Quantized, Quantizer};
