//! Gradient compression stack.
//!
//! The paper's contribution ([`cosine`]) plus every baseline it compares
//! against, the composition machinery ([`codec`]), the lossless stage
//! ([`deflate`], built from scratch), and the byte-exact wire format
//! ([`wire`]) the simulated network meters.
//!
//! Pipeline (client → server):
//!
//! ```text
//!  g = M_in − M*  ──sparsify (seeded mask)──►  kept values
//!      ──quantize (cosine/linear/…, s bits)──►  codes + norm + bound
//!      ──bitpack (s bits/code)──►  bytes  ──DEFLATE──►  wire payload
//! ```
//!
//! The server reverses every stage; the decoded dense gradient feeds
//! FedAvg aggregation (Eq. 1).

pub mod bitpack;
pub mod codec;
pub mod cosine;
pub mod deflate;
pub mod entropy;
pub mod hadamard;
pub mod linear;
pub mod signsgd;
pub mod sparsify;
pub mod topk;
pub mod wire;

pub use codec::{ClientCodecState, Codec, CodecKind, EncodedGradient};
