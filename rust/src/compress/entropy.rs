//! Byte-stream information statistics for Figure 5: multi-scale entropy of
//! quantized-gradient codes vs raw float32 bytes, and accumulated DEFLATE
//! compression-ratio curves.
//!
//! The paper's argument (§4): quantized gradients concentrate on few byte
//! patterns (low entropy at every scale), so a generic lossless coder
//! compresses them 3–4× further, while adjacent float32 values share almost
//! no byte patterns (entropy ≈ 8 bits/byte).

use std::collections::HashMap;

use super::deflate;

/// Shannon entropy of `data` viewed as a stream of `scale`-byte symbols,
/// normalized to **bits per byte** (so a uniform random stream → 8.0 at
/// every scale and any value below 8 indicates exploitable structure).
pub fn entropy_bits_per_byte(data: &[u8], scale: usize) -> f64 {
    assert!(scale >= 1);
    if data.len() < scale {
        return 0.0;
    }
    let mut counts: HashMap<&[u8], u64> = HashMap::new();
    let n_symbols = data.len() / scale;
    for i in 0..n_symbols {
        *counts.entry(&data[i * scale..(i + 1) * scale]).or_insert(0) += 1;
    }
    let n = n_symbols as f64;
    let bits_per_symbol: f64 = counts
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum();
    bits_per_symbol / scale as f64
}

/// Multi-scale entropy profile at scales 1, 2, 4, 8 bytes.
pub fn multiscale_entropy(data: &[u8]) -> Vec<(usize, f64)> {
    [1usize, 2, 4, 8]
        .iter()
        .map(|&s| (s, entropy_bits_per_byte(data, s)))
        .collect()
}

/// Probe cap for [`accumulated_compression_curve`]: each point compresses
/// at most this many bytes (at `Fast` level) and reports the sample's
/// ratio for the whole prefix. Keeps entropy probing off the round budget
/// — the allocator consults this curve every plan, and a full
/// `Default`-level pass over the probe window was a second complete
/// compression per round.
const PROBE_CAP: usize = 64 * 1024;

/// Accumulated compression-ratio curve: for growing prefixes of `data`,
/// `ratio(i) = prefix_len / deflate(prefix).len()`. Returns
/// `(prefix_len, ratio)` pairs at `points` log-spaced sizes — the paper's
/// Fig. 5 right panel. Prefixes beyond [`PROBE_CAP`] are sampled: the
/// ratio of the first `PROBE_CAP` bytes stands in for the full prefix.
pub fn accumulated_compression_curve(data: &[u8], points: usize) -> Vec<(usize, f64)> {
    let mut out = Vec::with_capacity(points);
    if data.is_empty() || points == 0 {
        return out;
    }
    let min_len = 256.min(data.len());
    for k in 0..points {
        let t = (k + 1) as f64 / points as f64;
        let len = ((min_len as f64)
            * ((data.len() as f64 / min_len as f64).powf(t)))
        .round() as usize;
        let len = len.clamp(1, data.len());
        let probe = len.min(PROBE_CAP);
        let compressed =
            deflate::deflate(&data[..probe], deflate::CompressionLevel::Fast)
                .len()
                .max(1);
        out.push((len, probe as f64 / compressed as f64));
    }
    out
}

/// Reinterpret an f32 slice as little-endian bytes (the float32 baseline
/// stream of Fig. 5).
pub fn f32_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    f32_bytes_into(xs, &mut out);
    out
}

/// [`f32_bytes`] into a reusable buffer (cleared first).
pub fn f32_bytes_into(xs: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn constant_stream_has_zero_entropy() {
        let data = vec![42u8; 4096];
        for scale in [1usize, 2, 4, 8] {
            assert!(entropy_bits_per_byte(&data, scale) < 1e-9);
        }
    }

    #[test]
    fn random_stream_has_high_scale1_entropy() {
        let mut rng = Pcg64::seeded(101);
        let data: Vec<u8> = (0..1 << 16).map(|_| rng.next_u32() as u8).collect();
        let e1 = entropy_bits_per_byte(&data, 1);
        assert!(e1 > 7.9, "e1={e1}");
    }

    #[test]
    fn two_symbol_stream_is_one_bit() {
        let mut rng = Pcg64::seeded(102);
        let data: Vec<u8> = (0..1 << 14)
            .map(|_| if rng.bernoulli(0.5) { 0u8 } else { 255u8 })
            .collect();
        let e1 = entropy_bits_per_byte(&data, 1);
        assert!((e1 - 1.0).abs() < 0.02, "e1={e1}");
    }

    #[test]
    fn quantized_codes_have_lower_entropy_than_float_bytes() {
        // Fig. 5's core claim at unit-test scale.
        let mut rng = Pcg64::seeded(103);
        let g = crate::util::propcheck::gradient_like(&mut rng, 30_000);
        let quant =
            crate::compress::cosine::CosineQuantizer::paper_default(8).quantize(&g, &mut rng);
        let packed = crate::compress::bitpack::pack(&quant.codes, 8);
        let floats = f32_bytes(&g);
        for scale in [1usize, 2] {
            let eq = entropy_bits_per_byte(&packed, scale);
            let ef = entropy_bits_per_byte(&floats, scale);
            assert!(eq < ef - 1.0, "scale={scale}: {eq} !< {ef}-1");
        }
    }

    #[test]
    fn compression_curve_monotone_sizes() {
        let mut rng = Pcg64::seeded(104);
        let data = crate::util::propcheck::compressible_bytes(&mut rng, 20_000);
        let curve = accumulated_compression_curve(&data, 8);
        assert_eq!(curve.len(), 8);
        assert!(curve.windows(2).all(|w| w[0].0 <= w[1].0));
        assert_eq!(curve.last().unwrap().0, 20_000);
        // Compressible data: final ratio is substantially > 1.
        assert!(curve.last().unwrap().1 > 2.0);
    }

    #[test]
    fn probe_cap_extrapolates_long_prefixes() {
        let data = vec![9u8; PROBE_CAP * 2];
        let curve = accumulated_compression_curve(&data, 4);
        assert_eq!(curve.last().unwrap().0, PROBE_CAP * 2);
        // The capped sample still reports the (very high) run ratio.
        assert!(curve.last().unwrap().1 > 10.0);
    }

    #[test]
    fn f32_bytes_layout() {
        assert_eq!(f32_bytes(&[1.0]), 1.0f32.to_le_bytes().to_vec());
        assert_eq!(f32_bytes(&[]).len(), 0);
    }
}
