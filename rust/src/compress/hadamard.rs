//! Randomized Hadamard rotation (Suresh et al. [40]; the "R" in the paper's
//! "linear (U, R)" baseline [17]).
//!
//! The rotation `R = (1/√d) · H · D` — `H` the Walsh–Hadamard matrix, `D` a
//! seeded random ±1 diagonal — spreads the gradient's energy uniformly
//! across coordinates before linear quantization, shrinking `max|x|` and
//! therefore the quantization error. `R` is orthonormal, so the server
//! inverts with `Rᵀ = (1/√d) · D · H`. Only the seed travels on the wire.
//!
//! Implementation: in-place fast Walsh–Hadamard transform (O(d log d)),
//! inputs padded to the next power of two.

use crate::util::rng::Pcg64;

/// In-place (unnormalized) fast Walsh–Hadamard transform.
/// `data.len()` must be a power of two.
pub fn fwht(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "fwht length {n} not a power of two");
    let mut h = 1;
    while h < n {
        for chunk in data.chunks_exact_mut(h * 2) {
            let (a, b) = chunk.split_at_mut(h);
            for i in 0..h {
                let x = a[i];
                let y = b[i];
                a[i] = x + y;
                b[i] = x - y;
            }
        }
        h *= 2;
    }
}

// The seeded ±1 diagonal (64 signs per PCG word, stream 0xD1A6) is
// applied streaming inside rotate_into/unrotate_into — deterministic, so
// the server regenerates it from the wire seed rather than receiving d
// bytes, and no sign buffer is ever materialized.

/// Next power of two ≥ n (n ≥ 1).
pub fn padded_len(n: usize) -> usize {
    n.next_power_of_two()
}

/// Forward rotation: pad `g` to a power of two, apply `(1/√d)·H·D`.
/// Returns the rotated vector of length `padded_len(g.len())`.
pub fn rotate(g: &[f32], seed: u64) -> Vec<f32> {
    let mut out = Vec::new();
    rotate_into(g, seed, &mut out);
    out
}

/// [`rotate`] into a reusable buffer. The ±1 diagonal is applied
/// streaming off the RNG words (64 signs per draw, in index order), so
/// the only storage is the output itself.
pub fn rotate_into(g: &[f32], seed: u64, out: &mut Vec<f32>) {
    let d = padded_len(g.len().max(1));
    out.clear();
    out.resize(d, 0.0);
    let mut rng = Pcg64::new(seed, 0xD1A6);
    let mut i = 0usize;
    while i < d.min(g.len()) {
        let mut word = rng.next_u64();
        for _ in 0..64.min(d - i) {
            if i < g.len() {
                out[i] = if word & 1 == 1 { g[i] } else { -g[i] };
            }
            word >>= 1;
            i += 1;
        }
    }
    fwht(out);
    let scale = 1.0 / (d as f32).sqrt();
    for v in out.iter_mut() {
        *v *= scale;
    }
}

/// Inverse rotation: apply `(1/√d)·D·H` and truncate to `n`.
pub fn unrotate(x: &[f32], seed: u64, n: usize) -> Vec<f32> {
    let mut out = Vec::new();
    unrotate_into(x, seed, n, &mut out);
    out
}

/// [`unrotate`] into a reusable buffer.
pub fn unrotate_into(x: &[f32], seed: u64, n: usize, out: &mut Vec<f32>) {
    let d = x.len();
    assert!(d.is_power_of_two(), "unrotate length {d} not a power of two");
    assert!(n <= d);
    out.clear();
    out.extend_from_slice(x);
    fwht(out);
    let scale = 1.0 / (d as f32).sqrt();
    // Stream the diagonal over the first n lanes (the rest are padding).
    let mut rng = Pcg64::new(seed, 0xD1A6);
    let mut i = 0usize;
    while i < n {
        let mut word = rng.next_u64();
        for _ in 0..64.min(d - i) {
            if i < n {
                let s = if word & 1 == 1 { 1.0 } else { -1.0 };
                out[i] = out[i] * scale * s;
            }
            word >>= 1;
            i += 1;
        }
    }
    out.truncate(n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{forall, gradient_like};
    use crate::util::stats::l2_norm;

    #[test]
    fn fwht_matches_direct_hadamard_4() {
        // H_4 applied to e_1 gives [1,1,1,1]; to [1,2,3,4] gives known values.
        let mut x = [1.0f32, 2.0, 3.0, 4.0];
        fwht(&mut x);
        assert_eq!(x, [10.0, -2.0, -4.0, 0.0]);
    }

    #[test]
    fn fwht_involution_up_to_n() {
        let mut rng = Pcg64::seeded(41);
        for pow in [1usize, 4, 7] {
            let n = 1 << pow;
            let orig: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut x = orig.clone();
            fwht(&mut x);
            fwht(&mut x);
            for (a, b) in orig.iter().zip(&x) {
                assert!((a * n as f32 - b).abs() < 1e-3 * n as f32);
            }
        }
    }

    #[test]
    fn rotation_roundtrips() {
        forall(
            40,
            42,
            |rng, size| { let n = size.len(rng) * 3 + 1; gradient_like(rng, n) },
            |g| {
                let rot = rotate(g, 123);
                let back = unrotate(&rot, 123, g.len());
                g.iter()
                    .zip(&back)
                    .all(|(&a, &b)| (a - b).abs() < 1e-4 * (1.0 + a.abs()))
            },
        );
    }

    #[test]
    fn rotation_preserves_norm() {
        let mut rng = Pcg64::seeded(43);
        let g = gradient_like(&mut rng, 1000);
        let rot = rotate(&g, 9);
        let n0 = l2_norm(&g);
        let n1 = l2_norm(&rot);
        assert!((n0 - n1).abs() < 1e-3 * n0.max(1.0), "{n0} vs {n1}");
    }

    #[test]
    fn rotation_flattens_spikes() {
        // The whole point: a single dominant coordinate spreads out, so
        // max|x| shrinks toward ‖g‖/√d.
        let mut g = vec![0.0f32; 1024];
        g[17] = 5.0;
        let rot = rotate(&g, 7);
        let max_before = 5.0f32;
        let max_after = rot.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(
            max_after < max_before / 4.0,
            "max_after={max_after} should be ~5/32"
        );
    }

    #[test]
    fn different_seeds_give_different_rotations() {
        let g: Vec<f32> = (0..64).map(|i| (i as f32 * 0.1).sin()).collect();
        let a = rotate(&g, 1);
        let b = rotate(&g, 2);
        assert!(a.iter().zip(&b).any(|(x, y)| (x - y).abs() > 1e-3));
        // But each inverts correctly with its own seed.
        let back = unrotate(&b, 2, g.len());
        for (x, y) in g.iter().zip(&back) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
