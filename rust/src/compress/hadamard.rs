//! Randomized Hadamard rotation (Suresh et al. [40]; the "R" in the paper's
//! "linear (U, R)" baseline [17]).
//!
//! The rotation `R = (1/√d) · H · D` — `H` the Walsh–Hadamard matrix, `D` a
//! seeded random ±1 diagonal — spreads the gradient's energy uniformly
//! across coordinates before linear quantization, shrinking `max|x|` and
//! therefore the quantization error. `R` is orthonormal, so the server
//! inverts with `Rᵀ = (1/√d) · D · H`. Only the seed travels on the wire.
//!
//! Implementation: in-place fast Walsh–Hadamard transform (O(d log d)),
//! inputs padded to the next power of two.

use crate::util::rng::Pcg64;

/// In-place (unnormalized) fast Walsh–Hadamard transform.
/// `data.len()` must be a power of two.
pub fn fwht(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "fwht length {n} not a power of two");
    let mut h = 1;
    while h < n {
        for chunk in data.chunks_exact_mut(h * 2) {
            let (a, b) = chunk.split_at_mut(h);
            for i in 0..h {
                let x = a[i];
                let y = b[i];
                a[i] = x + y;
                b[i] = x - y;
            }
        }
        h *= 2;
    }
}

/// Seeded ±1 diagonal. Deterministic: the server regenerates it from the
/// wire seed rather than receiving d bytes.
fn rademacher(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 0xD1A6);
    let mut out = Vec::with_capacity(n);
    // 64 signs per draw.
    let mut i = 0;
    while i < n {
        let mut word = rng.next_u64();
        for _ in 0..64.min(n - i) {
            out.push(if word & 1 == 1 { 1.0 } else { -1.0 });
            word >>= 1;
            i += 1;
        }
    }
    out
}

/// Next power of two ≥ n (n ≥ 1).
pub fn padded_len(n: usize) -> usize {
    n.next_power_of_two()
}

/// Forward rotation: pad `g` to a power of two, apply `(1/√d)·H·D`.
/// Returns the rotated vector of length `padded_len(g.len())`.
pub fn rotate(g: &[f32], seed: u64) -> Vec<f32> {
    let d = padded_len(g.len().max(1));
    let signs = rademacher(seed, d);
    let mut x = vec![0.0f32; d];
    for (i, &v) in g.iter().enumerate() {
        x[i] = v * signs[i];
    }
    fwht(&mut x);
    let scale = 1.0 / (d as f32).sqrt();
    for v in &mut x {
        *v *= scale;
    }
    x
}

/// Inverse rotation: apply `(1/√d)·D·H` and truncate to `n`.
pub fn unrotate(x: &[f32], seed: u64, n: usize) -> Vec<f32> {
    let d = x.len();
    assert!(d.is_power_of_two(), "unrotate length {d} not a power of two");
    assert!(n <= d);
    let signs = rademacher(seed, d);
    let mut y = x.to_vec();
    fwht(&mut y);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push(y[i] * scale * signs[i]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{forall, gradient_like};
    use crate::util::stats::l2_norm;

    #[test]
    fn fwht_matches_direct_hadamard_4() {
        // H_4 applied to e_1 gives [1,1,1,1]; to [1,2,3,4] gives known values.
        let mut x = [1.0f32, 2.0, 3.0, 4.0];
        fwht(&mut x);
        assert_eq!(x, [10.0, -2.0, -4.0, 0.0]);
    }

    #[test]
    fn fwht_involution_up_to_n() {
        let mut rng = Pcg64::seeded(41);
        for pow in [1usize, 4, 7] {
            let n = 1 << pow;
            let orig: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut x = orig.clone();
            fwht(&mut x);
            fwht(&mut x);
            for (a, b) in orig.iter().zip(&x) {
                assert!((a * n as f32 - b).abs() < 1e-3 * n as f32);
            }
        }
    }

    #[test]
    fn rotation_roundtrips() {
        forall(
            40,
            42,
            |rng, size| { let n = size.len(rng) * 3 + 1; gradient_like(rng, n) },
            |g| {
                let rot = rotate(g, 123);
                let back = unrotate(&rot, 123, g.len());
                g.iter()
                    .zip(&back)
                    .all(|(&a, &b)| (a - b).abs() < 1e-4 * (1.0 + a.abs()))
            },
        );
    }

    #[test]
    fn rotation_preserves_norm() {
        let mut rng = Pcg64::seeded(43);
        let g = gradient_like(&mut rng, 1000);
        let rot = rotate(&g, 9);
        let n0 = l2_norm(&g);
        let n1 = l2_norm(&rot);
        assert!((n0 - n1).abs() < 1e-3 * n0.max(1.0), "{n0} vs {n1}");
    }

    #[test]
    fn rotation_flattens_spikes() {
        // The whole point: a single dominant coordinate spreads out, so
        // max|x| shrinks toward ‖g‖/√d.
        let mut g = vec![0.0f32; 1024];
        g[17] = 5.0;
        let rot = rotate(&g, 7);
        let max_before = 5.0f32;
        let max_after = rot.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(
            max_after < max_before / 4.0,
            "max_after={max_after} should be ~5/32"
        );
    }

    #[test]
    fn different_seeds_give_different_rotations() {
        let g: Vec<f32> = (0..64).map(|i| (i as f32 * 0.1).sin()).collect();
        let a = rotate(&g, 1);
        let b = rotate(&g, 2);
        assert!(a.iter().zip(&b).any(|(x, y)| (x - y).abs() > 1e-3));
        // But each inverts correctly with its own seed.
        let back = unrotate(&b, 2, g.len());
        for (x, y) in g.iter().zip(&back) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
