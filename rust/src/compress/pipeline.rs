//! Direction-agnostic compression pipeline (Algorithm 1, both arrows):
//!
//! ```text
//!   values ──EF fold──► sparsify ──► rotate ──► quantize ──► bit-pack ──► DEFLATE
//! ```
//!
//! One [`Pipeline`] value composes a [`Quantizer`] with the structural
//! stages. [`Pipeline::encode`] turns a dense tensor (an uplink gradient
//! *or* a downlink model delta) into an [`EncodedTensor`] — what travels
//! on the wire — and the free function [`decode`] inverts it anywhere,
//! driven entirely by the self-describing wire header: the receiver never
//! needs the sender's configuration.
//!
//! Stage notes:
//! * **error feedback** (Karimireddy et al. [15], generalized): fold the
//!   local residual into the input, quantize, and carry the reconstruction
//!   error forward in [`PipelineState`]. Works with any quantizer; with
//!   [`super::quantizer::EfSign`] it is exactly EF-signSGD.
//! * **sparsify**: seeded random mask [17]; only the seed travels.
//! * **rotate**: randomized Hadamard rotation [40] (the "R" in
//!   "linear (U, R)"); composes with any quantizer since CSG2.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::util::rng::Pcg64;

use super::bitpack;
use super::cosine::{BoundMode, CosineQuantizer, Rounding};
use super::deflate::{self, CompressionLevel};
use super::entropy;
use super::hadamard;
use super::kernel::KernelScratch;
use super::linear::{LinearQuantizer, ValueBound};
use super::quantizer::{self, EfSign, Float32Passthrough, Quantizer, SignSgd, SignSgdNorm};
use super::signsgd;
use super::sparsify;

/// Which way a tensor travels. Tags every wire frame so cost ledgers and
/// replicas can't confuse a gradient update with a model delta.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Client → server (gradient update).
    Uplink,
    /// Server → client (model delta broadcast).
    Downlink,
}

impl Direction {
    /// Stable wire id.
    pub fn id(&self) -> u8 {
        match self {
            Direction::Uplink => 0,
            Direction::Downlink => 1,
        }
    }

    pub fn from_id(id: u8) -> Result<Direction> {
        match id {
            0 => Ok(Direction::Uplink),
            1 => Ok(Direction::Downlink),
            other => anyhow::bail!("bad direction id {other}"),
        }
    }
}

/// A complete compression scheme: one quantizer plus the structural
/// stages. Cheap to clone (the quantizer is shared).
#[derive(Debug, Clone)]
pub struct Pipeline {
    quantizer: Arc<dyn Quantizer>,
    /// Fraction of coordinates transmitted (random mask [17]); 1.0 = all.
    pub keep_frac: f64,
    /// Randomized Hadamard rotation before quantization.
    pub rotate: bool,
    /// Fold the [`PipelineState`] residual in before encoding and carry
    /// the reconstruction error forward (EF memory never hits the wire).
    pub error_feedback: bool,
    /// Apply DEFLATE to the packed payload (§4).
    pub deflate: bool,
    pub level: CompressionLevel,
    /// Worker threads for the DEFLATE stage (0 = auto, 1 = serial).
    /// Scheduling only — output bytes are identical at every value.
    pub deflate_threads: usize,
}

impl Pipeline {
    /// A pipeline around any quantizer: dense, unrotated, DEFLATE on.
    pub fn new<Q: Quantizer + 'static>(q: Q) -> Pipeline {
        Pipeline {
            quantizer: Arc::new(q),
            keep_frac: 1.0,
            rotate: false,
            error_feedback: false,
            deflate: true,
            level: CompressionLevel::Default,
            deflate_threads: 1,
        }
    }

    /// Uncompressed float32 baseline (no DEFLATE — matching the paper's
    /// float32 cost accounting; Fig. 5 shows it would gain only ~1.07×).
    pub fn float32() -> Pipeline {
        Pipeline::new(Float32Passthrough).without_deflate()
    }

    /// The paper's default CosSGD config at `bits` (biased, top-1% clip).
    pub fn cosine(bits: u8) -> Pipeline {
        Pipeline::new(CosineQuantizer::paper_default(bits))
    }

    /// CosSGD with explicit rounding / bound mode.
    pub fn cosine_with(bits: u8, rounding: Rounding, bound: BoundMode) -> Pipeline {
        Pipeline::new(CosineQuantizer::new(bits, rounding, bound))
    }

    /// Value-space linear quantization ("linear" / "linear (U)").
    pub fn linear(bits: u8, rounding: Rounding) -> Pipeline {
        Pipeline::new(LinearQuantizer::new(bits, rounding, ValueBound::MaxAbs))
    }

    /// Linear after a randomized Hadamard rotation ("linear (U, R)").
    pub fn linear_rotated(bits: u8, rounding: Rounding) -> Pipeline {
        Pipeline::linear(bits, rounding).with_rotation()
    }

    /// signSGD [4]: signs only, unit magnitude.
    pub fn sign() -> Pipeline {
        Pipeline::new(SignSgd)
    }

    /// signSGD+Norm [43] — identical to 1-bit CosSGD.
    pub fn sign_norm() -> Pipeline {
        Pipeline::new(SignSgdNorm)
    }

    /// EF-signSGD [15]: ℓ₁-scaled signs with client-local error feedback.
    pub fn ef_sign() -> Pipeline {
        Pipeline::new(EfSign).with_error_feedback()
    }

    pub fn with_sparsify(mut self, keep_frac: f64) -> Pipeline {
        assert!((0.0..=1.0).contains(&keep_frac));
        self.keep_frac = keep_frac;
        self
    }

    pub fn with_rotation(mut self) -> Pipeline {
        self.rotate = true;
        self
    }

    pub fn with_error_feedback(mut self) -> Pipeline {
        self.error_feedback = true;
        self
    }

    pub fn without_deflate(mut self) -> Pipeline {
        self.deflate = false;
        self
    }

    /// Set the DEFLATE compression level (`--deflate-level`).
    pub fn with_deflate_level(mut self, level: CompressionLevel) -> Pipeline {
        self.level = level;
        self
    }

    /// Set the DEFLATE worker thread count (`--deflate-threads`, 0 =
    /// auto). Output bytes are identical at every value; only wall-clock
    /// changes.
    pub fn with_deflate_threads(mut self, threads: usize) -> Pipeline {
        self.deflate_threads = threads;
        self
    }

    /// The same pipeline at a different code width — what the adaptive
    /// bit controller ([`super::allocator`]) reconfigures per round /
    /// per layer. Rounding, bound mode and every structural stage are
    /// preserved; at the current width this is an exact clone, so
    /// `const:<b>` schedules stay byte-identical to the fixed-width
    /// path. Fixed-width quantizers (the sign family, float32
    /// passthrough) ignore the request — their width is their identity.
    pub fn with_bits(&self, bits: u8) -> Pipeline {
        if bits == self.quantizer.bits() {
            return self.clone();
        }
        let mut out = self.clone();
        if let Some(cq) = self.quantizer.as_any().downcast_ref::<CosineQuantizer>() {
            out.quantizer = Arc::new(CosineQuantizer::new(bits, cq.rounding, cq.bound));
        } else if let Some(lq) = self.quantizer.as_any().downcast_ref::<LinearQuantizer>() {
            out.quantizer = Arc::new(LinearQuantizer::new(bits, lq.rounding, lq.bound));
        }
        out
    }

    /// The quantizer stage (for introspection / kernel offload).
    pub fn quantizer(&self) -> &dyn Quantizer {
        self.quantizer.as_ref()
    }

    /// Bits per transmitted code.
    pub fn bits(&self) -> u8 {
        self.quantizer.bits()
    }

    /// Full scheme label: quantizer, EF, rotation, sparsification and
    /// DEFLATE status — every stage that changes bytes-on-wire is visible
    /// in figure labels.
    pub fn name(&self) -> String {
        let mut s = self.quantizer.name();
        if self.error_feedback {
            s = format!("EF-{s}");
        }
        if self.rotate {
            s.push_str(" +R");
        }
        if self.keep_frac < 1.0 {
            s.push_str(&format!(" @{}%", (self.keep_frac * 100.0).round()));
        }
        if self.deflate {
            s.push_str(" +deflate");
        }
        s
    }

    /// Encode a dense tensor travelling in `direction`. `rng` drives
    /// stochastic rounding and the mask/rotation seeds; `state` carries
    /// the error-feedback residual across rounds (unused otherwise).
    ///
    /// Convenience wrapper over [`Pipeline::encode_with`] paying one
    /// fresh [`EncodeScratch`] per call — long-lived endpoints (clients,
    /// the server) hold a scratch and call `encode_with` directly.
    pub fn encode(
        &self,
        values: &[f32],
        direction: Direction,
        state: &mut PipelineState,
        rng: &mut Pcg64,
    ) -> EncodedTensor {
        self.encode_with(values, direction, state, rng, &mut EncodeScratch::new())
    }

    /// [`Pipeline::encode`] with caller-owned scratch: every intermediate
    /// stage buffer (EF fold, gather, rotation, codes, packed bytes, EF
    /// reconstruction) lives in `scratch` and is reused across rounds, so
    /// the steady state performs no stage allocations and no stage
    /// copies — the dense un-sparsified path quantizes straight off the
    /// caller's slice. The one buffer that still leaves the arena is the
    /// payload itself, which must escape into the returned
    /// [`EncodedTensor`]; `scratch` donates its packed buffer for it and
    /// re-grows the next round.
    pub fn encode_with(
        &self,
        values: &[f32],
        direction: Direction,
        state: &mut PipelineState,
        rng: &mut Pcg64,
        scratch: &mut EncodeScratch,
    ) -> EncodedTensor {
        let staged = self.run_stages(values, state, rng, scratch);

        // --- deflate -------------------------------------------------------
        let (payload, deflated) = if self.deflate {
            scratch.deflated.clear();
            let stats = deflate::deflate_into(
                &scratch.packed,
                self.level,
                self.deflate_threads,
                &mut scratch.deflated,
            );
            let helped = scratch.deflated.len() < scratch.packed.len();
            scratch.last_deflate = Some(stats);
            if helped {
                (std::mem::take(&mut scratch.deflated), true)
            } else {
                (std::mem::take(&mut scratch.packed), false)
            }
        } else {
            scratch.last_deflate = None;
            (std::mem::take(&mut scratch.packed), false)
        };
        staged.into_tensor(self, direction, deflated, payload)
    }

    /// [`Pipeline::encode_with`] fused with wire serialization: the frame
    /// header lands in `out` first and the DEFLATE stage then streams its
    /// compressed bytes straight into `out` behind it — serialization
    /// overlaps compression, with no intermediate payload `Vec`. Returns
    /// the frame metadata with an **empty** `payload`; the bytes live in
    /// `out` and parse back via [`super::wire::deserialize`]. The appended
    /// bytes are identical to `serialize(&encode_with(..))` at every
    /// thread count.
    pub fn encode_wire_with(
        &self,
        values: &[f32],
        direction: Direction,
        state: &mut PipelineState,
        rng: &mut Pcg64,
        scratch: &mut EncodeScratch,
        out: &mut Vec<u8>,
    ) -> EncodedTensor {
        let staged = self.run_stages(values, state, rng, scratch);
        let mut enc = staged.into_tensor(self, direction, false, Vec::new());
        let deflated = super::wire::serialize_with(&enc, out, |buf| {
            if self.deflate {
                let base = buf.len();
                let stats = deflate::deflate_into(
                    &scratch.packed,
                    self.level,
                    self.deflate_threads,
                    buf,
                );
                scratch.last_deflate = Some(stats);
                if buf.len() - base < scratch.packed.len() {
                    return true;
                }
                // DEFLATE didn't help: fall back to the packed bytes.
                buf.truncate(base);
            } else {
                scratch.last_deflate = None;
            }
            buf.extend_from_slice(&scratch.packed);
            false
        });
        enc.deflated = deflated;
        enc
    }

    /// The shared stage chain (EF fold → sparsify → rotate → quantize →
    /// pack), leaving the packed payload in `scratch.packed`.
    fn run_stages(
        &self,
        values: &[f32],
        state: &mut PipelineState,
        rng: &mut Pcg64,
        scratch: &mut EncodeScratch,
    ) -> StagedFrame {
        let n = values.len();

        // --- error-feedback fold ------------------------------------------
        let work_ref: &[f32] = if self.error_feedback {
            if state.residual.len() != n {
                // First use (or model resize): cold-start the memory.
                state.residual = vec![0.0; n];
            }
            scratch.work.clear();
            scratch
                .work
                .extend(values.iter().zip(&state.residual).map(|(&v, &e)| v + e));
            &scratch.work
        } else {
            values
        };

        // --- sparsify ------------------------------------------------------
        let (mask_seed, mask) = if self.keep_frac < 1.0 {
            let seed = rng.next_u64();
            let m = sparsify::mask(seed, n, self.keep_frac);
            sparsify::gather_into(work_ref, &m, &mut scratch.gathered);
            (seed, Some(m))
        } else {
            (0u64, None)
        };
        let kept_ref: &[f32] = if mask.is_some() {
            &scratch.gathered
        } else {
            work_ref
        };
        let kept_n = kept_ref.len();

        // --- rotate --------------------------------------------------------
        let (rot_seed, stage_ref): (u64, &[f32]) = if self.rotate {
            let seed = rng.next_u64();
            hadamard::rotate_into(kept_ref, seed, &mut scratch.rotated);
            (seed, &scratch.rotated)
        } else {
            (0u64, kept_ref)
        };

        // --- quantize + pack ----------------------------------------------
        let bits = self.quantizer.bits();
        let (norm, bound) = if bits == 32 {
            // Float passthrough: raw little-endian floats, no bit-packing.
            entropy::f32_bytes_into(stage_ref, &mut scratch.packed);
            (0.0, 0.0)
        } else {
            let (norm, bound) =
                self.quantizer
                    .quantize_into(stage_ref, rng, &mut scratch.kernel, &mut scratch.codes);
            bitpack::pack_into(&scratch.codes, bits, &mut scratch.packed);
            (norm, bound)
        };

        // --- error-feedback residual update -------------------------------
        if self.error_feedback {
            if bits == 32 {
                scratch.rec.clear();
                scratch.rec.extend_from_slice(stage_ref);
            } else {
                self.quantizer.dequantize_into(
                    &scratch.codes,
                    norm,
                    bound,
                    &mut scratch.kernel,
                    &mut scratch.rec,
                );
            }
            let rec_stage: &[f32] = if self.rotate {
                hadamard::unrotate_into(&scratch.rec, rot_seed, kept_n, &mut scratch.rec_dense);
                &scratch.rec_dense
            } else {
                &scratch.rec
            };
            match &mask {
                Some(m) => {
                    // Streaming scatter: unsent coordinates reconstruct as
                    // zero, so their residual is the full withheld value.
                    let mut kept_iter = m.kept.iter().zip(rec_stage);
                    let mut next = kept_iter.next();
                    for (i, (e, &p)) in state.residual.iter_mut().zip(work_ref).enumerate() {
                        let r = match next {
                            Some((&ki, &rv)) if ki == i => {
                                next = kept_iter.next();
                                rv
                            }
                            _ => 0.0,
                        };
                        *e = p - r;
                    }
                }
                None => {
                    for ((e, &p), &r) in state.residual.iter_mut().zip(work_ref).zip(rec_stage) {
                        *e = p - r;
                    }
                }
            }
        }

        StagedFrame {
            bits,
            n: n as u32,
            kept: kept_n as u32,
            mask_seed,
            rot_seed,
            norm,
            bound,
        }
    }

    /// Codes actually transmitted for `n`-element tensors (pre-pack;
    /// rotation pads to the next power of two).
    pub fn transmitted_codes(&self, n: usize) -> usize {
        let kept = if self.keep_frac < 1.0 {
            sparsify::kept_count(n, self.keep_frac)
        } else {
            n
        };
        if self.rotate {
            hadamard::padded_len(kept.max(1))
        } else {
            kept
        }
    }
}

/// Everything [`Pipeline::run_stages`] learned about a frame except the
/// payload bytes (those stay in the scratch arena until the caller
/// decides where they go: an owned `payload` Vec or the wire buffer).
struct StagedFrame {
    bits: u8,
    n: u32,
    kept: u32,
    mask_seed: u64,
    rot_seed: u64,
    norm: f32,
    bound: f32,
}

impl StagedFrame {
    fn into_tensor(
        self,
        pipe: &Pipeline,
        direction: Direction,
        deflated: bool,
        payload: Vec<u8>,
    ) -> EncodedTensor {
        EncodedTensor {
            direction,
            kind_id: pipe.quantizer.id(),
            bits: self.bits,
            n: self.n,
            kept: self.kept,
            mask_seed: self.mask_seed,
            rot_seed: self.rot_seed,
            rotated: pipe.rotate,
            norm: self.norm,
            bound: self.bound,
            deflated,
            payload,
        }
    }
}

/// Decode an [`EncodedTensor`] into a dense vector of length `enc.n`,
/// using only the wire header (quantizer id/bits, rotation flag, mask
/// seed) — no sender configuration required.
pub fn decode(enc: &EncodedTensor) -> Result<Vec<f32>> {
    decode_with(enc, &mut EncodeScratch::new())
}

/// [`decode`] with caller-owned scratch: the unpacked codes and the
/// dequantize LUTs are reused across rounds. The payload is *borrowed*
/// when no DEFLATE stage is present (it used to be cloned wholesale);
/// only the final dense vector is allocated — it is the result.
pub fn decode_with(enc: &EncodedTensor, scratch: &mut EncodeScratch) -> Result<Vec<f32>> {
    let inflated;
    let raw: &[u8] = if enc.deflated {
        inflated = deflate::inflate(&enc.payload)?;
        &inflated
    } else {
        &enc.payload
    };
    let kept = enc.kept as usize;
    let n = enc.n as usize;
    let count = if enc.rotated {
        hadamard::padded_len(kept.max(1))
    } else {
        kept
    };

    let stage_values: Vec<f32> = if enc.kind_id == quantizer::ids::FLOAT32 {
        ensure!(enc.bits == 32, "float32 frame with bits {}", enc.bits);
        ensure!(
            raw.len() == count * 4,
            "float32 payload size {} != {}",
            raw.len(),
            count * 4
        );
        raw.chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect()
    } else {
        ensure!(
            raw.len() >= bitpack::packed_len(count, enc.bits),
            "payload too short: {} bytes for {count} codes of {} bits",
            raw.len(),
            enc.bits
        );
        bitpack::unpack_into(raw, enc.bits, count, &mut scratch.codes);
        let q = quantizer::from_wire(enc.kind_id, enc.bits)?;
        let mut out = Vec::new();
        q.dequantize_into(&scratch.codes, enc.norm, enc.bound, &mut scratch.kernel, &mut out);
        out
    };

    let values = if enc.rotated {
        hadamard::unrotate(&stage_values, enc.rot_seed, kept)
    } else {
        stage_values
    };

    if enc.mask_seed != 0 && kept < n {
        let m = sparsify::mask(enc.mask_seed, n, kept as f64 / n as f64);
        ensure!(
            m.kept.len() == kept,
            "mask regeneration mismatch: {} vs {kept}",
            m.kept.len()
        );
        Ok(sparsify::scatter(&values, &m))
    } else {
        Ok(values)
    }
}

/// Fused decode+accumulate: fold `decode(enc)[i] · w` into `acc[i]`
/// without materializing the decoded vector — the server's frame-ingest
/// hot path. For dense unrotated frames (the paper's default pipelines)
/// the packed codes are unpacked into `scratch` and folded straight
/// through the quantizer's level LUTs ([`Quantizer::accumulate_into`]);
/// float32 passthrough folds directly from the payload bytes. Rotated or
/// sparsified frames fall back to [`decode_with`] + add. Either way the
/// result is **bit-identical** to decode-then-add: the per-element f32
/// value and the `f32 → f64` mul-add are the same operations in the same
/// order (asserted in `tests/kernel_equivalence.rs`).
pub fn accumulate_with(
    enc: &EncodedTensor,
    w: f64,
    acc: &mut [f64],
    scratch: &mut EncodeScratch,
) -> Result<()> {
    let n = enc.n as usize;
    ensure!(
        n == acc.len(),
        "update length {} != accumulator {}",
        n,
        acc.len()
    );
    // kept == n ⇒ the payload is dense in coordinate order even when a
    // mask seed is present (an all-kept mask gathers the identity).
    if enc.rotated || (enc.kept as usize) != n {
        let delta = decode_with(enc, scratch)?;
        for (a, &d) in acc.iter_mut().zip(&delta) {
            *a += d as f64 * w;
        }
        return Ok(());
    }
    let inflated;
    let raw: &[u8] = if enc.deflated {
        inflated = deflate::inflate(&enc.payload)?;
        &inflated
    } else {
        &enc.payload
    };
    if enc.kind_id == quantizer::ids::FLOAT32 {
        ensure!(enc.bits == 32, "float32 frame with bits {}", enc.bits);
        ensure!(
            raw.len() == n * 4,
            "float32 payload size {} != {}",
            raw.len(),
            n * 4
        );
        for (a, b) in acc.iter_mut().zip(raw.chunks_exact(4)) {
            *a += f32::from_le_bytes([b[0], b[1], b[2], b[3]]) as f64 * w;
        }
        return Ok(());
    }
    ensure!(
        raw.len() >= bitpack::packed_len(n, enc.bits),
        "payload too short: {} bytes for {n} codes of {} bits",
        raw.len(),
        enc.bits
    );
    bitpack::unpack_into(raw, enc.bits, n, &mut scratch.codes);
    // Boxless fused dispatch: `from_wire(..)` would heap-allocate a
    // `Box<dyn Quantizer>` per (client, tensor) in the ingest hot loop.
    quantizer::accumulate_wire(
        enc.kind_id,
        enc.bits,
        &scratch.codes,
        enc.norm,
        enc.bound,
        &mut scratch.kernel,
        w,
        acc,
    )?;
    Ok(())
}

/// Sub-range fused accumulate: fold elements `start..start + acc.len()`
/// of a dense, unrotated, **already-inflated** frame into `acc` — the
/// worker-side kernel of the sharded ingest plane
/// ([`crate::fl::ingest`]), where each shard owns a contiguous slice of
/// the server accumulator and folds only its intersection with every
/// frame/segment.
///
/// Bit-exactness contract (vs [`accumulate_with`] over the full frame):
/// * the packed codes are a pure LSB-first function of bit position, so
///   [`bitpack::unpack_range_into`] yields exactly
///   `unpack_into(..)[start..]`;
/// * every per-element reconstruction is position-independent given the
///   wire-header scalars. The one length-dependent scheme — signSGD+Norm,
///   whose magnitude is `norm/√n` — is computed here from the header's
///   full `n`, not the sub-range length. The cosine/linear LUT-vs-direct
///   branch may differ between a sub-range and the full tensor, but each
///   LUT entry *is* the direct formula evaluated once, so the folded
///   values are identical either way.
///
/// Pinned against the serial path in `tests/kernel_equivalence.rs`.
pub fn accumulate_range_with(
    enc: &EncodedTensor,
    start: usize,
    w: f64,
    acc: &mut [f64],
    scratch: &mut EncodeScratch,
) -> Result<()> {
    let n = enc.n as usize;
    let len = acc.len();
    ensure!(!enc.deflated, "range accumulate needs an inflated payload");
    ensure!(
        !enc.rotated && enc.kept as usize == n,
        "range accumulate needs a dense unrotated frame"
    );
    ensure!(
        start + len <= n,
        "range {start}..{} exceeds frame length {n}",
        start + len
    );
    let raw: &[u8] = &enc.payload;
    if enc.kind_id == quantizer::ids::FLOAT32 {
        ensure!(enc.bits == 32, "float32 frame with bits {}", enc.bits);
        ensure!(
            raw.len() == n * 4,
            "float32 payload size {} != {}",
            raw.len(),
            n * 4
        );
        let sub = &raw[start * 4..(start + len) * 4];
        for (a, b) in acc.iter_mut().zip(sub.chunks_exact(4)) {
            *a += f32::from_le_bytes([b[0], b[1], b[2], b[3]]) as f64 * w;
        }
        return Ok(());
    }
    ensure!(
        raw.len() >= bitpack::packed_len(n, enc.bits),
        "payload too short: {} bytes for {n} codes of {} bits",
        raw.len(),
        enc.bits
    );
    bitpack::unpack_range_into(raw, enc.bits, start, len, &mut scratch.codes);
    if enc.kind_id == quantizer::ids::SIGN_NORM {
        // ±‖g‖₂/√n: the magnitude depends on the FULL tensor length, so
        // it must not be recomputed from the sub-range code count (which
        // is what `SignSgdNorm::accumulate_into` would do).
        let mag = enc.norm / (n.max(1) as f32).sqrt();
        signsgd::accumulate_signs(&scratch.codes, mag, w, acc);
        return Ok(());
    }
    quantizer::accumulate_wire(
        enc.kind_id,
        enc.bits,
        &scratch.codes,
        enc.norm,
        enc.bound,
        &mut scratch.kernel,
        w,
        acc,
    )?;
    Ok(())
}

/// Per-endpoint pipeline memory: the error-feedback residual. Client-local
/// on the uplink, server-local on the downlink; never transmitted.
#[derive(Debug, Clone, Default)]
pub struct PipelineState {
    pub residual: Vec<f32>,
}

impl PipelineState {
    pub fn new() -> PipelineState {
        Self::default()
    }
}

/// Reusable stage buffers for [`Pipeline::encode_with`] /
/// [`decode_with`]: one per long-lived endpoint (each [`crate::fl::client::Client`]
/// and the server own one), so steady-state rounds run the whole
/// EF → sparsify → rotate → quantize → pack chain without touching the
/// allocator. Distinct from [`PipelineState`], which is *semantic* memory
/// (the EF residual) — dropping a scratch never changes results.
#[derive(Debug, Clone, Default)]
pub struct EncodeScratch {
    /// EF-folded input (`values + residual`).
    work: Vec<f32>,
    /// Gathered (sparsified) coordinates.
    gathered: Vec<f32>,
    /// Rotated stage values (padded to a power of two).
    rotated: Vec<f32>,
    /// Quantizer output codes (also the decode-side unpack buffer).
    codes: Vec<u16>,
    /// Bit-packed payload bytes (donated to the frame each round).
    packed: Vec<u8>,
    /// DEFLATE output staging (donated when compression helps).
    deflated: Vec<u8>,
    /// Telemetry from the most recent DEFLATE stage (`None` when the
    /// stage was skipped — deflate off, or decode-only use).
    last_deflate: Option<deflate::DeflateStats>,
    /// EF reconstruction of the stage values.
    rec: Vec<f32>,
    /// EF reconstruction after un-rotation.
    rec_dense: Vec<f32>,
    /// Threshold / LUT tables for the transcendental-free kernels.
    kernel: KernelScratch,
}

impl EncodeScratch {
    pub fn new() -> EncodeScratch {
        Self::default()
    }

    /// Telemetry from the most recent encode's DEFLATE stage (chunk count,
    /// bytes in/out, per-worker contributions), or `None` if that encode
    /// skipped compression. Feeds the round metrics in `fl::runner`.
    pub fn deflate_stats(&self) -> Option<&deflate::DeflateStats> {
        self.last_deflate.as_ref()
    }
}

/// A compressed tensor as it travels on the wire, either direction.
/// Serialized byte-exactly by [`super::wire`].
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedTensor {
    pub direction: Direction,
    pub kind_id: u8,
    pub bits: u8,
    /// Full (dense) tensor length.
    pub n: u32,
    /// Transmitted coordinate count (before rotation padding).
    pub kept: u32,
    pub mask_seed: u64,
    pub rot_seed: u64,
    pub rotated: bool,
    pub norm: f32,
    pub bound: f32,
    pub deflated: bool,
    pub payload: Vec<u8>,
}

impl EncodedTensor {
    /// Total bytes on the wire (header + payload) — the quantity every
    /// cost table in the paper measures. See [`super::wire`] for the
    /// exact serialization this counts.
    pub fn wire_bytes(&self) -> usize {
        super::wire::HEADER_BYTES + self.payload.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::gradient_like;
    use crate::util::stats::l2_norm;

    fn state() -> PipelineState {
        PipelineState::new()
    }

    fn enc(p: &Pipeline, g: &[f32], rng: &mut Pcg64) -> EncodedTensor {
        p.encode(g, Direction::Uplink, &mut state(), rng)
    }

    fn rel_err(a: &[f32], b: &[f32]) -> f64 {
        let diff: f64 = a
            .iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        diff / l2_norm(a).max(1e-12)
    }

    fn cos_sim(a: &[f32], b: &[f32]) -> f64 {
        let dot: f64 = a.iter().zip(b).map(|(&x, &y)| (x * y) as f64).sum();
        dot / (l2_norm(a) * l2_norm(b)).max(1e-12)
    }

    #[test]
    fn cosine_8bit_roundtrip_accurate() {
        // Per-element angle error is ≤ q/2, so the L2 relative error scales
        // like sqrt(n/3)·q/2 ≈ 0.35 at n=10k — assert we stay within that
        // analytic envelope and that the *direction* is well preserved.
        let mut rng = Pcg64::seeded(111);
        let g = gradient_like(&mut rng, 10_000);
        // Auto bound (no saturation) so every element obeys the envelope;
        // top-p% clipping deliberately sacrifices the top tail (Table 2).
        let pipe = Pipeline::cosine_with(8, Rounding::Biased, BoundMode::Auto);
        let e = enc(&pipe, &g, &mut rng);
        let dec = decode(&e).unwrap();
        assert_eq!(dec.len(), g.len());
        let q = (std::f32::consts::PI - 2.0 * e.bound) / 255.0;
        let envelope = ((g.len() as f64) / 3.0).sqrt() * (q as f64) / 2.0 * 1.2 + 1e-3;
        assert!(
            rel_err(&g, &dec) < envelope,
            "rel err {} > envelope {envelope}",
            rel_err(&g, &dec)
        );
        assert!(cos_sim(&g, &dec) > 0.93, "cosine similarity {}", cos_sim(&g, &dec));
    }

    #[test]
    fn clipping_concentrates_error_on_top_tail() {
        // With top-1% clipping the saturated elements absorb the error while
        // the bulk is reconstructed finely — the paper's Table 2 mechanism.
        let mut rng = Pcg64::seeded(211);
        let g = gradient_like(&mut rng, 10_000);
        let pipe = Pipeline::cosine(8);
        let dec = decode(&enc(&pipe, &g, &mut rng)).unwrap();
        let k = 100; // top 1%
        let thresh = crate::util::stats::kth_largest_abs(&g, k);
        let (mut bulk_err, mut bulk_scale, mut nbulk) = (0.0f64, 0.0f64, 0usize);
        for (&a, &b) in g.iter().zip(&dec) {
            if a.abs() < thresh {
                bulk_err += ((a - b) as f64).powi(2);
                bulk_scale += (a as f64).powi(2);
                nbulk += 1;
            }
        }
        assert!(nbulk >= 9_800);
        let bulk_rel = (bulk_err / bulk_scale.max(1e-12)).sqrt();
        assert!(bulk_rel < 0.25, "bulk rel err {bulk_rel}");
    }

    #[test]
    fn all_schemes_roundtrip_dense_shape() {
        let mut rng = Pcg64::seeded(112);
        let g = gradient_like(&mut rng, 3000);
        let pipes = [
            Pipeline::float32(),
            Pipeline::cosine_with(2, Rounding::Unbiased, BoundMode::Auto),
            Pipeline::linear(4, Rounding::Biased),
            Pipeline::linear_rotated(2, Rounding::Unbiased),
            Pipeline::sign(),
            Pipeline::sign_norm(),
            Pipeline::ef_sign(),
        ];
        for pipe in pipes {
            for keep in [1.0, 0.25] {
                let pipe = pipe.clone().with_sparsify(keep);
                let mut st = state();
                let e = pipe.encode(&g, Direction::Uplink, &mut st, &mut rng);
                let dec = decode(&e).unwrap();
                assert_eq!(dec.len(), g.len(), "{}", pipe.name());
                if keep < 1.0 {
                    let zeros = dec.iter().filter(|&&x| x == 0.0).count();
                    assert!(
                        zeros >= (g.len() as f64 * 0.7) as usize,
                        "{}: sparsified decode should be mostly zero ({zeros})",
                        pipe.name()
                    );
                }
            }
        }
    }

    #[test]
    fn float32_roundtrip_exact() {
        let mut rng = Pcg64::seeded(113);
        let g = gradient_like(&mut rng, 513);
        let pipe = Pipeline::float32();
        assert_eq!(decode(&enc(&pipe, &g, &mut rng)).unwrap(), g);
    }

    #[test]
    fn sparsified_decode_preserves_kept_exactly_float32() {
        let mut rng = Pcg64::seeded(114);
        let g = gradient_like(&mut rng, 800);
        let pipe = Pipeline::float32().with_sparsify(0.1);
        let e = enc(&pipe, &g, &mut rng);
        let dec = decode(&e).unwrap();
        let m = sparsify::mask(e.mask_seed, g.len(), 0.1);
        for &i in &m.kept {
            assert_eq!(dec[i], g[i]);
        }
        assert_eq!(dec.iter().filter(|&&x| x != 0.0).count(), m.kept.len());
    }

    #[test]
    fn rotated_linear_beats_plain_linear_with_outlier() {
        // The rotation's raison d'être: a dominating coordinate ruins plain
        // linear 2-bit; rotation spreads it.
        let mut rng = Pcg64::seeded(115);
        let mut g = gradient_like(&mut rng, 4096);
        g[7] = 25.0;
        let plain = Pipeline::linear(2, Rounding::Unbiased);
        let rotated = Pipeline::linear_rotated(2, Rounding::Unbiased);
        let mut e_plain = 0.0;
        let mut e_rot = 0.0;
        for _ in 0..5 {
            let dp = decode(&enc(&plain, &g, &mut rng)).unwrap();
            let dr = decode(&enc(&rotated, &g, &mut rng)).unwrap();
            e_plain += rel_err(&g, &dp);
            e_rot += rel_err(&g, &dr);
        }
        assert!(e_rot < e_plain, "rot {e_rot} !< plain {e_plain}");
    }

    #[test]
    fn rotation_composes_with_any_quantizer() {
        // New in CSG2: rotation is a pipeline stage, so cosine +R decodes
        // correctly too (CSG1 could only fuse rotation into linear).
        let mut rng = Pcg64::seeded(120);
        let g = gradient_like(&mut rng, 2000);
        let pipe = Pipeline::cosine(8).with_rotation();
        let e = enc(&pipe, &g, &mut rng);
        assert!(e.rotated);
        let dec = decode(&e).unwrap();
        assert_eq!(dec.len(), g.len());
        assert!(cos_sim(&g, &dec) > 0.9, "sim {}", cos_sim(&g, &dec));
    }

    #[test]
    fn cosine_2bit_beats_linear_2bit_biased() {
        // Figures 6/7 (a) in miniature: biased linear 2-bit reconstruction
        // is much worse than biased cosine 2-bit on gradient-like data.
        let mut rng = Pcg64::seeded(116);
        let g = gradient_like(&mut rng, 20_000);
        let cos = Pipeline::cosine(2);
        let lin = Pipeline::linear(2, Rounding::Biased);
        let dc = decode(&enc(&cos, &g, &mut rng)).unwrap();
        let dl = decode(&enc(&lin, &g, &mut rng)).unwrap();
        assert!(
            cos_sim(&g, &dc) > cos_sim(&g, &dl),
            "cosine sim {} !> linear sim {}",
            cos_sim(&g, &dc),
            cos_sim(&g, &dl)
        );
    }

    #[test]
    fn wire_cost_reduction_matches_bits() {
        let mut rng = Pcg64::seeded(117);
        let g = gradient_like(&mut rng, 100_000);
        let f32_cost = enc(&Pipeline::float32(), &g, &mut rng).wire_bytes();
        let q8 = Pipeline::cosine(8).without_deflate();
        let cost8 = enc(&q8, &g, &mut rng).wire_bytes();
        let ratio = f32_cost as f64 / cost8 as f64;
        assert!((3.5..4.5).contains(&ratio), "8-bit ratio {ratio}");
        // With DEFLATE the paper reports >10x total for 8-bit (Fig. 5).
        let cost8d = enc(&Pipeline::cosine(8), &g, &mut rng).wire_bytes();
        let ratio_d = f32_cost as f64 / cost8d as f64;
        assert!(ratio_d > 6.0, "deflated 8-bit ratio {ratio_d}");
    }

    #[test]
    fn deflate_flag_falls_back_when_incompressible() {
        let mut rng = Pcg64::seeded(118);
        let g = gradient_like(&mut rng, 4000);
        let e = enc(&Pipeline::float32(), &g, &mut rng);
        assert!(!e.deflated); // float32() disables deflate
    }

    #[test]
    fn ef_with_mask_keeps_residual_for_unsent() {
        let mut rng = Pcg64::seeded(119);
        let g = vec![1.0f32; 64];
        let pipe = Pipeline::ef_sign().with_sparsify(0.25);
        let mut st = state();
        let e = pipe.encode(&g, Direction::Uplink, &mut st, &mut rng);
        let dec = decode(&e).unwrap();
        // Unsent coordinates: residual should hold their full value.
        let m = sparsify::mask(e.mask_seed, g.len(), 0.25);
        let kept: std::collections::HashSet<usize> = m.kept.iter().copied().collect();
        for i in 0..g.len() {
            if !kept.contains(&i) {
                assert_eq!(dec[i], 0.0);
                assert!((st.residual[i] - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn ef_compensates_over_time() {
        // Repeatedly sending the SAME input: with EF, the cumulative
        // reconstruction converges to the cumulative true signal (residual
        // stays bounded), whereas plain sign loses magnitude info.
        let g = [0.9f32, -0.1, 0.05, -0.02];
        let pipe = Pipeline::ef_sign().without_deflate();
        let mut st = state();
        let mut rng = Pcg64::seeded(121);
        let mut cum = [0.0f32; 4];
        let steps = 200;
        for _ in 0..steps {
            let e = pipe.encode(&g, Direction::Uplink, &mut st, &mut rng);
            for (c, r) in cum.iter_mut().zip(decode(&e).unwrap()) {
                *c += r;
            }
        }
        for (i, (&ci, &gi)) in cum.iter().zip(&g).enumerate() {
            let target = gi * steps as f32;
            // Error is bounded by the residual, not growing with steps.
            assert!(
                (ci - target).abs() <= 2.0 * 0.9 + 1e-3,
                "i={i} cum={ci} target={target}"
            );
        }
    }

    #[test]
    fn ef_generalizes_to_other_quantizers() {
        // EF around the cosine quantizer: residual tracks exactly the
        // reconstruction error of the quantized frame.
        let mut rng = Pcg64::seeded(122);
        let g = gradient_like(&mut rng, 256);
        let pipe = Pipeline::cosine(4).with_error_feedback();
        let mut st = state();
        let e = pipe.encode(&g, Direction::Uplink, &mut st, &mut rng);
        let dec = decode(&e).unwrap();
        for ((&gi, &di), &ri) in g.iter().zip(&dec).zip(&st.residual) {
            assert!((ri - (gi - di)).abs() < 1e-5, "{ri} vs {}", gi - di);
        }
    }

    #[test]
    fn direction_tag_is_carried() {
        let mut rng = Pcg64::seeded(123);
        let g = gradient_like(&mut rng, 64);
        let pipe = Pipeline::cosine(4);
        let up = pipe.encode(&g, Direction::Uplink, &mut state(), &mut rng);
        let down = pipe.encode(&g, Direction::Downlink, &mut state(), &mut rng);
        assert_eq!(up.direction, Direction::Uplink);
        assert_eq!(down.direction, Direction::Downlink);
        // Direction never changes the payload semantics.
        assert_eq!(decode(&up).unwrap().len(), decode(&down).unwrap().len());
    }

    #[test]
    fn transmitted_codes_counts() {
        let c = Pipeline::cosine(2).with_sparsify(0.05);
        assert_eq!(c.transmitted_codes(1000), 50);
        let r = Pipeline::linear_rotated(2, Rounding::Unbiased).with_sparsify(0.05);
        assert_eq!(r.transmitted_codes(1000), 64); // padded to pow2
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // encode_with/decode_with against ONE scratch reused across
        // schemes and sizes (the stale-buffer hazard) must match the
        // allocating entry points exactly — frames, residuals and decodes.
        let mut rng = Pcg64::seeded(130);
        let g = gradient_like(&mut rng, 3000);
        let mut scratch = EncodeScratch::new();
        for pipe in [
            Pipeline::cosine(4),
            Pipeline::float32(),
            Pipeline::cosine(2).with_sparsify(0.25),
            Pipeline::cosine(8).with_rotation(),
            Pipeline::ef_sign(),
            Pipeline::ef_sign().with_sparsify(0.25),
            Pipeline::linear(4, Rounding::Biased),
            Pipeline::cosine_with(3, Rounding::Unbiased, BoundMode::Auto),
        ] {
            for size in [3000usize, 777, 1] {
                let gs = &g[..size];
                let mut st1 = state();
                let mut st2 = state();
                let a = pipe.encode(gs, Direction::Uplink, &mut st1, &mut Pcg64::new(5, 1));
                let b = pipe.encode_with(
                    gs,
                    Direction::Uplink,
                    &mut st2,
                    &mut Pcg64::new(5, 1),
                    &mut scratch,
                );
                assert_eq!(a, b, "{} n={size}", pipe.name());
                assert_eq!(st1.residual, st2.residual, "{} n={size}", pipe.name());
                let d1 = decode(&a).unwrap();
                let d2 = decode_with(&b, &mut scratch).unwrap();
                assert_eq!(d1, d2, "{} n={size}", pipe.name());
            }
        }
    }

    #[test]
    fn with_bits_preserves_configuration() {
        // Same width → exact clone (the const-schedule identity).
        let base = Pipeline::cosine_with(4, Rounding::Unbiased, BoundMode::Auto)
            .with_sparsify(0.5)
            .without_deflate();
        let same = base.with_bits(4);
        assert_eq!(same.name(), base.name());
        let mut rng1 = Pcg64::seeded(7);
        let mut rng2 = Pcg64::seeded(7);
        let g = gradient_like(&mut Pcg64::seeded(1), 500);
        let a = base.encode(&g, Direction::Uplink, &mut state(), &mut rng1);
        let b = same.encode(&g, Direction::Uplink, &mut state(), &mut rng2);
        assert_eq!(a, b);
        // New width keeps rounding/bound/stages; only the width moves.
        let wide = base.with_bits(8);
        assert_eq!(wide.bits(), 8);
        assert_eq!(wide.name(), "cosine-8 (U) @50%");
        // Reconfigured == constructed from scratch.
        let direct = Pipeline::cosine_with(8, Rounding::Unbiased, BoundMode::Auto)
            .with_sparsify(0.5)
            .without_deflate();
        let c = wide.encode(&g, Direction::Uplink, &mut state(), &mut Pcg64::seeded(7));
        let d = direct.encode(&g, Direction::Uplink, &mut state(), &mut Pcg64::seeded(7));
        assert_eq!(c, d);
        // Fixed-width schemes ignore the request.
        assert_eq!(Pipeline::sign().with_bits(4).bits(), 1);
        assert_eq!(Pipeline::float32().with_bits(4).bits(), 32);
        // Linear keeps its rounding too.
        assert_eq!(
            Pipeline::linear(4, Rounding::Unbiased).with_bits(2).name(),
            "linear-2 (U) +deflate"
        );
    }

    #[test]
    fn names_expose_every_stage() {
        assert_eq!(Pipeline::float32().name(), "float32");
        assert_eq!(Pipeline::cosine(2).name(), "cosine-2 +deflate");
        assert_eq!(
            Pipeline::cosine(2).with_sparsify(0.05).without_deflate().name(),
            "cosine-2 @5%"
        );
        assert_eq!(
            Pipeline::linear_rotated(2, Rounding::Unbiased).name(),
            "linear-2 (U) +R +deflate"
        );
        assert_eq!(Pipeline::ef_sign().name(), "EF-signSGD(l1) +deflate");
    }
}
