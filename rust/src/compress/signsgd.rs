//! 1-bit baselines: signSGD [4], signSGD+Norm [43] and EF-signSGD [15].
//!
//! * **signSGD** transmits only the sign of each coordinate; the server
//!   treats `sign(g)` as the update (magnitude is folded into η_s).
//! * **signSGD+Norm** additionally transmits `‖g‖₂` and reconstructs
//!   `sign(g)·‖g‖₂/√n` — norm-preserving; the paper notes this is exactly
//!   CosSGD's 1-bit degenerate case.
//! * **EF-signSGD** keeps a per-client residual `e`: compress
//!   `p = g + e` as `(‖p‖₁/n)·sign(p)` and carry `e ← p − compressed`
//!   forward. The residual is client-local state — never transmitted.

use crate::util::stats::l2_norm;

/// Sign bits of a vector (1 = non-negative). One code per element, ready
/// for 1-bit packing.
pub fn sign_codes(g: &[f32]) -> Vec<u16> {
    g.iter().map(|&x| (x >= 0.0) as u16).collect()
}

/// signSGD reconstruction: ±1 per coordinate.
pub fn decode_sign(codes: &[u16]) -> Vec<f32> {
    codes
        .iter()
        .map(|&c| if c == 1 { 1.0 } else { -1.0 })
        .collect()
}

/// signSGD+Norm reconstruction: ±‖g‖₂/√n per coordinate (preserves ‖g‖₂).
pub fn decode_sign_norm(codes: &[u16], norm: f32) -> Vec<f32> {
    let n = codes.len().max(1);
    let mag = norm / (n as f32).sqrt();
    codes
        .iter()
        .map(|&c| if c == 1 { mag } else { -mag })
        .collect()
}

/// Per-client error-feedback memory for EF-signSGD.
#[derive(Debug, Clone, Default)]
pub struct ErrorFeedback {
    pub residual: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(n: usize) -> Self {
        Self {
            residual: vec![0.0; n],
        }
    }

    /// Encode `g` with error feedback. Returns `(codes, scale)`; the
    /// reconstruction is `scale · sign(p)` with `p = g + e`, and the
    /// residual is updated in place (Karimireddy et al. [15], Alg. 1).
    pub fn encode(&mut self, g: &[f32]) -> (Vec<u16>, f32) {
        if self.residual.len() != g.len() {
            // First use (or model resize): cold-start the memory.
            self.residual = vec![0.0; g.len()];
        }
        let p: Vec<f32> = g
            .iter()
            .zip(&self.residual)
            .map(|(&gi, &ei)| gi + ei)
            .collect();
        let n = p.len().max(1);
        let scale = p.iter().map(|x| x.abs()).sum::<f32>() / n as f32; // ‖p‖₁/n
        let codes = sign_codes(&p);
        for (ei, (&pi, &ci)) in self.residual.iter_mut().zip(p.iter().zip(&codes)) {
            let rec = if ci == 1 { scale } else { -scale };
            *ei = pi - rec;
        }
        (codes, scale)
    }
}

/// EF-signSGD reconstruction: `scale · sign`.
pub fn decode_ef(codes: &[u16], scale: f32) -> Vec<f32> {
    codes
        .iter()
        .map(|&c| if c == 1 { scale } else { -scale })
        .collect()
}

/// Convenience: ‖g‖₂ as f32 (shared by the codecs).
pub fn norm2(g: &[f32]) -> f32 {
    l2_norm(g) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::gradient_like;
    use crate::util::rng::Pcg64;

    #[test]
    fn signs_preserved() {
        let g = [1.5f32, -0.2, 0.0, -7.0];
        assert_eq!(sign_codes(&g), vec![1, 0, 1, 0]);
        assert_eq!(decode_sign(&sign_codes(&g)), vec![1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn sign_norm_preserves_l2_norm() {
        let mut rng = Pcg64::seeded(51);
        let g = gradient_like(&mut rng, 4096);
        let norm = norm2(&g);
        let rec = decode_sign_norm(&sign_codes(&g), norm);
        let rec_norm = norm2(&rec);
        assert!((rec_norm - norm).abs() < 1e-3 * norm, "{rec_norm} vs {norm}");
    }

    #[test]
    fn sign_norm_matches_cosine_one_bit_structure() {
        // Both produce ±c·‖g‖ with a single magnitude c and matching signs.
        let mut rng = Pcg64::seeded(52);
        let g = gradient_like(&mut rng, 256);
        let rec = decode_sign_norm(&sign_codes(&g), norm2(&g));
        let mags: Vec<f32> = rec.iter().map(|x| x.abs()).collect();
        for m in &mags {
            assert!((m - mags[0]).abs() < 1e-6);
        }
        for (a, b) in g.iter().zip(&rec) {
            if a.abs() > 0.0 {
                assert_eq!(a.signum(), b.signum());
            }
        }
    }

    #[test]
    fn error_feedback_residual_tracks_compression_error() {
        let mut ef = ErrorFeedback::new(4);
        let g = [1.0f32, -0.5, 0.25, -0.125];
        let (codes, scale) = ef.encode(&g);
        let rec = decode_ef(&codes, scale);
        for ((&gi, &ri), &ei) in g.iter().zip(&rec).zip(&ef.residual) {
            assert!((ei - (gi - ri)).abs() < 1e-6);
        }
    }

    #[test]
    fn error_feedback_compensates_over_time() {
        // Repeatedly sending the SAME gradient: with EF, the cumulative
        // reconstruction converges to the cumulative true signal
        // (residual stays bounded), whereas plain sign loses magnitude info.
        let g = [0.9f32, -0.1, 0.05, -0.02];
        let mut ef = ErrorFeedback::new(4);
        let mut cum = [0.0f32; 4];
        let steps = 200;
        for _ in 0..steps {
            let (codes, scale) = ef.encode(&g);
            for (c, r) in cum.iter_mut().zip(decode_ef(&codes, scale)) {
                *c += r;
            }
        }
        for (i, (&ci, &gi)) in cum.iter().zip(&g).enumerate() {
            let target = gi * steps as f32;
            // Error is bounded by the residual, not growing with steps.
            assert!(
                (ci - target).abs() <= 2.0 * 0.9 + 1e-3,
                "i={i} cum={ci} target={target}"
            );
        }
    }

    #[test]
    fn ef_cold_start_on_resize() {
        let mut ef = ErrorFeedback::new(2);
        let g = [1.0f32, 2.0, 3.0];
        let (codes, _) = ef.encode(&g);
        assert_eq!(codes.len(), 3);
        assert_eq!(ef.residual.len(), 3);
    }
}
