//! 1-bit code helpers for the sign family: signSGD [4], signSGD+Norm [43]
//! and the inner scheme of EF-signSGD [15].
//!
//! * **signSGD** transmits only the sign of each coordinate; the server
//!   treats `sign(g)` as the update (magnitude is folded into η_s).
//! * **signSGD+Norm** additionally transmits `‖g‖₂` and reconstructs
//!   `sign(g)·‖g‖₂/√n` — norm-preserving; the paper notes this is exactly
//!   CosSGD's 1-bit degenerate case.
//! * **EF-signSGD** compresses as `(‖p‖₁/n)·sign(p)`; the residual memory
//!   `e ← p − compressed` is the generalized error-feedback stage of
//!   [`super::pipeline::Pipeline`] (see `with_error_feedback`), carried in
//!   `PipelineState` — client-local, never transmitted.
//!
//! The `impl Quantizer` wrappers over these helpers live in
//! [`super::quantizer`].

use crate::util::stats::l2_norm;

/// Sign bits of a vector (1 = non-negative). One code per element, ready
/// for 1-bit packing.
pub fn sign_codes(g: &[f32]) -> Vec<u16> {
    let mut out = Vec::new();
    sign_codes_into(g, &mut out);
    out
}

/// [`sign_codes`] into a reusable buffer (cleared first).
pub fn sign_codes_into(g: &[f32], out: &mut Vec<u16>) {
    out.clear();
    out.extend(g.iter().map(|&x| (x >= 0.0) as u16));
}

/// Reconstruct `magnitude_of(code) · sign` into a reusable buffer — the
/// shared shape of all three sign-family decoders.
#[inline]
pub fn decode_signs_into(codes: &[u16], magnitude: f32, out: &mut Vec<f32>) {
    out.clear();
    out.extend(codes.iter().map(|&c| if c == 1 { magnitude } else { -magnitude }));
}

/// Fused sign dequantize+accumulate: `acc[i] += (±magnitude) · w` — the
/// sign-family arm of the server's one-pass frame ingest. Bit-identical
/// to [`decode_signs_into`] followed by the `f32 → f64` mul-add fold.
#[inline]
pub fn accumulate_signs(codes: &[u16], magnitude: f32, w: f64, acc: &mut [f64]) {
    debug_assert_eq!(codes.len(), acc.len());
    for (a, &c) in acc.iter_mut().zip(codes) {
        let v = if c == 1 { magnitude } else { -magnitude };
        *a += v as f64 * w;
    }
}

/// signSGD reconstruction: ±1 per coordinate.
pub fn decode_sign(codes: &[u16]) -> Vec<f32> {
    codes
        .iter()
        .map(|&c| if c == 1 { 1.0 } else { -1.0 })
        .collect()
}

/// signSGD+Norm reconstruction: ±‖g‖₂/√n per coordinate (preserves ‖g‖₂).
pub fn decode_sign_norm(codes: &[u16], norm: f32) -> Vec<f32> {
    let n = codes.len().max(1);
    let mag = norm / (n as f32).sqrt();
    codes
        .iter()
        .map(|&c| if c == 1 { mag } else { -mag })
        .collect()
}

/// EF-signSGD reconstruction: `scale · sign`.
pub fn decode_ef(codes: &[u16], scale: f32) -> Vec<f32> {
    codes
        .iter()
        .map(|&c| if c == 1 { scale } else { -scale })
        .collect()
}

/// Convenience: ‖g‖₂ as f32 (shared by the codecs).
pub fn norm2(g: &[f32]) -> f32 {
    l2_norm(g) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::gradient_like;
    use crate::util::rng::Pcg64;

    #[test]
    fn signs_preserved() {
        let g = [1.5f32, -0.2, 0.0, -7.0];
        assert_eq!(sign_codes(&g), vec![1, 0, 1, 0]);
        assert_eq!(decode_sign(&sign_codes(&g)), vec![1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn sign_norm_preserves_l2_norm() {
        let mut rng = Pcg64::seeded(51);
        let g = gradient_like(&mut rng, 4096);
        let norm = norm2(&g);
        let rec = decode_sign_norm(&sign_codes(&g), norm);
        let rec_norm = norm2(&rec);
        assert!((rec_norm - norm).abs() < 1e-3 * norm, "{rec_norm} vs {norm}");
    }

    #[test]
    fn sign_norm_matches_cosine_one_bit_structure() {
        // Both produce ±c·‖g‖ with a single magnitude c and matching signs.
        let mut rng = Pcg64::seeded(52);
        let g = gradient_like(&mut rng, 256);
        let rec = decode_sign_norm(&sign_codes(&g), norm2(&g));
        let mags: Vec<f32> = rec.iter().map(|x| x.abs()).collect();
        for m in &mags {
            assert!((m - mags[0]).abs() < 1e-6);
        }
        for (a, b) in g.iter().zip(&rec) {
            if a.abs() > 0.0 {
                assert_eq!(a.signum(), b.signum());
            }
        }
    }

    #[test]
    fn ef_scale_reconstruction() {
        let codes = [1u16, 0, 1, 1];
        assert_eq!(decode_ef(&codes, 0.5), vec![0.5, -0.5, 0.5, 0.5]);
    }
}
