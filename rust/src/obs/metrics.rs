//! Typed metrics registry: counters, gauges, and fixed-bucket histograms
//! keyed by `&'static str` names in `BTreeMap`s — so a snapshot
//! serializes in deterministic key order, and the registry itself can
//! never introduce iteration-order nondeterminism into a trace file.
//!
//! Two snapshot forms: [`Metrics::to_json`] (the final line of a
//! `--trace` JSONL file, consumed by `repro trace`) and
//! [`Metrics::prometheus`] (Prometheus text exposition, for scraping or
//! eyeballing).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// A fixed-bucket histogram: `counts[i]` tallies observations
/// `≤ bounds[i]`, with one implicit `+Inf` overflow bucket at the end.
#[derive(Debug, Clone, PartialEq)]
pub struct Hist {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    total: u64,
}

impl Hist {
    fn new(bounds: &[f64]) -> Hist {
        debug_assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Hist {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            total: 0,
        }
    }

    fn observe(&mut self, v: f64) {
        let i = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.sum += v;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }
}

/// The registry. Create once per run, thread `&mut` through the loop.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, Hist>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `by` to counter `name` (created at 0 on first touch).
    pub fn inc(&mut self, name: &'static str, by: u64) {
        *self.counters.entry(name).or_insert(0) += by;
    }

    /// Set gauge `name` (last write wins).
    pub fn set_gauge(&mut self, name: &'static str, v: f64) {
        self.gauges.insert(name, v);
    }

    /// Record `v` into histogram `name`, creating it with `bounds` on
    /// first touch (later calls keep the original buckets).
    pub fn observe(&mut self, name: &'static str, bounds: &[f64], v: f64) {
        self.hists.entry(name).or_insert_with(|| Hist::new(bounds)).observe(v);
    }

    /// Counter value (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value, if ever set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Histogram, if ever observed.
    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Deterministic JSON snapshot (the `{"metrics": …}` trace line).
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters = counters.set(k, *v);
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges = gauges.set(k, *v);
        }
        let mut hists = Json::obj();
        for (k, h) in &self.hists {
            let counts: Vec<Json> = h.counts.iter().map(|&c| Json::from(c)).collect();
            hists = hists.set(
                k,
                Json::obj()
                    .set("bounds", Json::from_f64_slice(&h.bounds))
                    .set("counts", counts)
                    .set("count", h.total)
                    .set("sum", h.sum),
            );
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("hists", hists)
    }

    /// Prometheus text exposition of the full registry.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("# TYPE {k} counter\n{k} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("# TYPE {k} gauge\n{k} {v}\n"));
        }
        for (k, h) in &self.hists {
            out.push_str(&format!("# TYPE {k} histogram\n"));
            let mut cum = 0u64;
            for (i, c) in h.counts.iter().enumerate() {
                cum += c;
                let le = match h.bounds.get(i) {
                    Some(b) => format!("{b}"),
                    None => "+Inf".to_string(),
                };
                out.push_str(&format!("{k}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{k}_sum {}\n{k}_count {}\n", h.sum, h.total));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let mut m = Metrics::new();
        assert!(m.is_empty());
        m.inc("ingest_accepted", 3);
        m.inc("ingest_accepted", 2);
        m.set_gauge("residual_norm", 1.5);
        m.set_gauge("residual_norm", 2.5);
        assert_eq!(m.counter("ingest_accepted"), 5);
        assert_eq!(m.counter("never"), 0);
        assert_eq!(m.gauge("residual_norm"), Some(2.5));
        assert_eq!(m.gauge("never"), None);
    }

    #[test]
    fn histogram_buckets_partition_observations() {
        let mut m = Metrics::new();
        let bounds = [1.0, 10.0, 100.0];
        for v in [0.5, 1.0, 5.0, 50.0, 500.0] {
            m.observe("frame_bytes", &bounds, v);
        }
        let h = m.hist("frame_bytes").unwrap();
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 556.5).abs() < 1e-9);
        assert_eq!(h.counts, vec![2, 1, 1, 1], "≤1, ≤10, ≤100, +Inf");
    }

    #[test]
    fn json_snapshot_is_deterministic() {
        let build = || {
            let mut m = Metrics::new();
            m.inc("z_last", 1);
            m.inc("a_first", 2);
            m.set_gauge("g", 0.25);
            m.observe("h", &[2.0], 1.0);
            m.to_json().dump()
        };
        let a = build();
        assert_eq!(a, build());
        // BTreeMap order: a_first before z_last.
        assert!(a.find("a_first").unwrap() < a.find("z_last").unwrap());
        Json::parse(&a).expect("snapshot parses");
    }

    #[test]
    fn prometheus_text_shape() {
        let mut m = Metrics::new();
        m.inc("uplink_bytes", 1234);
        m.set_gauge("queue_depth", 7.0);
        m.observe("staleness", &[0.0, 2.0], 1.0);
        let text = m.prometheus();
        assert!(text.contains("# TYPE uplink_bytes counter"));
        assert!(text.contains("uplink_bytes 1234"));
        assert!(text.contains("queue_depth 7"));
        assert!(text.contains("staleness_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("staleness_count 1"));
    }
}
