//! Span tracing over a bounded in-memory ring.
//!
//! A [`Tracer`] records [`Event`]s — span opens/closes and instant
//! points, each with a parent id taken from the open-span stack — into a
//! preallocated ring: when full, the oldest event is overwritten in
//! place (never a reallocation, pinned by `tests/obs_trace.rs`), so
//! instrumentation cost is bounded no matter how long a run is.
//!
//! Timestamps come from the tracer's [`TimeSource`]; serialization is one
//! compact JSON object per event ([`Tracer::to_jsonl`]), with
//! `BTreeMap`-ordered keys — under a deterministic clock, same seed ⇒
//! byte-identical trace, the contract `repro trace` and the CI artifact
//! rely on.

use crate::sim::Ticks;
use crate::util::json::Json;

use super::clock::TimeSource;

/// Handle to an open span, consumed by [`Tracer::close`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanId {
    id: u64,
    name: &'static str,
}

/// What an [`Event`] marks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A span began (`id` names it until the matching `Close`).
    Open,
    /// A span ended (same `id` as its `Open`).
    Close,
    /// An instant (no duration).
    Point,
}

impl EventKind {
    fn label(self) -> &'static str {
        match self {
            EventKind::Open => "open",
            EventKind::Close => "close",
            EventKind::Point => "point",
        }
    }
}

/// One structured trace event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Timestamp in ticks (µs) from the tracer's [`TimeSource`].
    pub at: Ticks,
    /// Span id (`Open`/`Close` pairs share it; `Point`s get their own).
    pub id: u64,
    /// Id of the enclosing open span, if any.
    pub parent: Option<u64>,
    pub kind: EventKind,
    pub name: &'static str,
    /// Structured payload, nested under `"f"` in the JSON form.
    pub fields: Vec<(&'static str, Json)>,
}

impl Event {
    /// One compact JSON object (deterministic key order).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("at", self.at)
            .set("ev", self.kind.label())
            .set("id", self.id)
            .set("name", self.name);
        if let Some(p) = self.parent {
            j = j.set("parent", p);
        }
        if !self.fields.is_empty() {
            let mut f = Json::obj();
            for (k, v) in &self.fields {
                f = f.set(k, v.clone());
            }
            j = j.set("f", f);
        }
        j
    }
}

/// The recording side of the tracing plane: a clock, an open-span stack,
/// and the bounded event ring.
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    clock: TimeSource,
    /// Preallocated to `cap`; never grows past it.
    ring: Vec<Event>,
    /// Ring capacity (a `Vec` may over-allocate; this is the logical cap).
    cap: usize,
    /// Index of the oldest event once the ring has wrapped.
    head: usize,
    /// Events overwritten after the ring filled.
    dropped: u64,
    next_id: u64,
    stack: Vec<u64>,
}

impl Tracer {
    /// An enabled tracer holding at most `capacity` events.
    pub fn new(clock: TimeSource, capacity: usize) -> Tracer {
        Tracer {
            enabled: true,
            clock,
            ring: Vec::with_capacity(capacity),
            cap: capacity,
            head: 0,
            dropped: 0,
            next_id: 1,
            stack: Vec::new(),
        }
    }

    /// A no-op tracer: every call returns immediately and records
    /// nothing — the tracing-off fast path (`bench_sim` guards that it
    /// stays event-free).
    pub fn disabled() -> Tracer {
        let mut t = Tracer::new(TimeSource::frozen(0), 0);
        t.enabled = false;
        t
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// `true` when this tracer's clock replays byte-identically per seed.
    pub fn is_deterministic(&self) -> bool {
        self.clock.is_deterministic()
    }

    /// Current clock reading.
    pub fn now(&self) -> Ticks {
        self.clock.now()
    }

    /// Drive a manual clock (see [`TimeSource::set_now`]).
    pub fn set_now(&mut self, t: Ticks) {
        self.clock.set_now(t);
    }

    /// Open a span under the current innermost open span.
    pub fn open(&mut self, name: &'static str) -> SpanId {
        self.open_with(name, Vec::new())
    }

    /// Open a span with structured fields on the open event.
    pub fn open_with(&mut self, name: &'static str, fields: Vec<(&'static str, Json)>) -> SpanId {
        let span = SpanId {
            id: self.next_id,
            name,
        };
        if !self.enabled {
            return span;
        }
        self.next_id += 1;
        let ev = Event {
            at: self.clock.now(),
            id: span.id,
            parent: self.stack.last().copied(),
            kind: EventKind::Open,
            name,
            fields,
        };
        self.stack.push(span.id);
        self.record(ev);
        span
    }

    /// Close `span` (and any still-open children — unbalanced closes pop
    /// through rather than corrupt the stack).
    pub fn close(&mut self, span: SpanId) {
        if !self.enabled {
            return;
        }
        while let Some(top) = self.stack.pop() {
            if top == span.id {
                break;
            }
        }
        let ev = Event {
            at: self.clock.now(),
            id: span.id,
            parent: self.stack.last().copied(),
            kind: EventKind::Close,
            name: span.name,
            fields: Vec::new(),
        };
        self.record(ev);
    }

    /// Record an instant event under the current open span.
    pub fn point(&mut self, name: &'static str, fields: Vec<(&'static str, Json)>) {
        if !self.enabled {
            return;
        }
        let ev = Event {
            at: self.clock.now(),
            id: self.next_id,
            parent: self.stack.last().copied(),
            kind: EventKind::Point,
            name,
            fields,
        };
        self.next_id += 1;
        self.record(ev);
    }

    fn record(&mut self, ev: Event) {
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.ring.len() < self.cap {
            self.ring.push(ev);
        } else {
            // Overwrite the oldest slot in place — no reallocation, ever.
            self.ring[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events currently held, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.ring[self.head..].iter().chain(self.ring[..self.head].iter())
    }

    /// Events currently held.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// The logical ring bound.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Bytes-level allocation witness: the backing buffer's capacity.
    /// Constant for the tracer's lifetime (pinned by the overflow test).
    pub fn allocated_capacity(&self) -> usize {
        self.ring.capacity()
    }

    /// Events overwritten (or discarded by a zero-capacity ring).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The whole ring as JSONL: one compact JSON object per line,
    /// oldest first.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in self.events() {
            out.push_str(&ev.to_json().dump());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_points_attach_to_the_open_span() {
        let mut t = Tracer::new(TimeSource::frozen(5), 16);
        let outer = t.open("round");
        let inner = t.open_with("train", vec![("client", Json::from(3usize))]);
        t.point("ingest", vec![("verdict", Json::from("accepted"))]);
        t.close(inner);
        t.close(outer);
        let evs: Vec<&Event> = t.events().collect();
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].kind, EventKind::Open);
        assert_eq!(evs[0].parent, None);
        assert_eq!(evs[1].parent, Some(evs[0].id));
        assert_eq!(evs[2].parent, Some(evs[1].id), "point under innermost span");
        assert_eq!(evs[3].kind, EventKind::Close);
        assert_eq!(evs[3].name, "train");
        assert_eq!(evs[4].name, "round");
        assert_eq!(evs[4].parent, None);
        assert!(t.to_jsonl().lines().count() == 5);
        for line in t.to_jsonl().lines() {
            Json::parse(line).expect("every trace line parses");
        }
    }

    #[test]
    fn ring_overflow_drops_oldest_without_reallocating() {
        let mut t = Tracer::new(TimeSource::frozen(0), 4);
        let alloc0 = t.allocated_capacity();
        for i in 0..10usize {
            t.point("p", vec![("i", Json::from(i))]);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 6);
        assert_eq!(t.capacity(), 4);
        assert_eq!(t.allocated_capacity(), alloc0, "ring must never reallocate");
        // Oldest first, and the survivors are the LAST four points.
        let is: Vec<usize> = t
            .events()
            .map(|e| e.fields[0].1.as_usize().unwrap())
            .collect();
        assert_eq!(is, vec![6, 7, 8, 9]);
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut t = Tracer::disabled();
        let s = t.open("round");
        t.point("ingest", Vec::new());
        t.close(s);
        assert_eq!(t.len(), 0);
        assert_eq!(t.dropped(), 0);
        assert!(t.to_jsonl().is_empty());
    }

    #[test]
    fn unbalanced_close_pops_through_children() {
        let mut t = Tracer::new(TimeSource::frozen(0), 16);
        let outer = t.open("outer");
        let _inner = t.open("inner");
        t.close(outer); // inner never closed explicitly
        t.point("after", Vec::new());
        let last = t.events().last().unwrap();
        assert_eq!(last.parent, None, "stack fully unwound by the outer close");
    }
}
