//! One phase model for both reporters: the simulator's per-round
//! critical-path breakdown ([`TimelineRecord`]) folded onto the span
//! model, so `repro sim` (which has a [`Timeline`]) and `repro trace`
//! (which has a parsed span stream) render the SAME [`PhaseBreakdown`]
//! through the same table code — one code path, two entry points.
//!
//! * [`emit_round_spans`] replays a completed timeline record into a
//!   tracer as a `round` span wrapping `broadcast`/`train`/`upload`
//!   children laid end-to-end on the critical path.
//! * [`PhaseBreakdown::from_timeline`] / [`PhaseBreakdown::from_events`]
//!   rebuild the rows from either side; byte-for-byte the trace route
//!   recovers exactly what the timeline route computes.

use std::collections::BTreeMap;

use crate::sim::{fmt_sim_secs, secs, Ticks, Timeline, TimelineRecord};
use crate::util::json::Json;

use super::trace::Tracer;

/// One round's (or async window's) critical-path phase split, in ticks.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseRow {
    pub round: usize,
    pub start: Ticks,
    pub end: Ticks,
    /// Downlink transfer of the round-closing reporter.
    pub broadcast: Ticks,
    /// Local training of the round-closing reporter.
    pub train: Ticks,
    /// Uplink transfer of the round-closing reporter.
    pub upload: Ticks,
    /// Uploads aggregated this round.
    pub reporters: usize,
}

impl PhaseRow {
    pub fn from_record(r: &TimelineRecord) -> PhaseRow {
        PhaseRow {
            round: r.round,
            start: r.start,
            end: r.end,
            broadcast: r.broadcast_ticks,
            train: r.compute_ticks,
            upload: r.upload_ticks,
            reporters: r.reporters,
        }
    }

    /// Round wall span in ticks.
    pub fn total(&self) -> Ticks {
        self.end - self.start
    }
}

/// Replay one completed timeline record into `tracer` as spans: the
/// round's wall span, with the critical-path phases as children laid
/// end-to-end from the round start. Rewinds the manual clock — call
/// after the live point events, it only ever appends.
pub fn emit_round_spans(tracer: &mut Tracer, r: &TimelineRecord) {
    tracer.set_now(r.start);
    let round = tracer.open_with(
        "round",
        vec![
            ("round", Json::from(r.round)),
            ("selected", Json::from(r.selected)),
            ("reporters", Json::from(r.reporters)),
            ("stragglers_dropped", Json::from(r.stragglers_dropped)),
            ("offline", Json::from(r.offline)),
            ("dropouts", Json::from(r.dropouts)),
        ],
    );
    let mut at = r.start;
    for (name, ticks) in [
        ("broadcast", r.broadcast_ticks),
        ("train", r.compute_ticks),
        ("upload", r.upload_ticks),
    ] {
        tracer.set_now(at);
        let span = tracer.open(name);
        at += ticks;
        tracer.set_now(at);
        tracer.close(span);
    }
    tracer.set_now(r.end);
    tracer.close(round);
}

/// Per-round phase rows plus the shared renderers.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PhaseBreakdown {
    pub rows: Vec<PhaseRow>,
}

impl PhaseBreakdown {
    /// The `repro sim` entry point: straight off the timeline.
    pub fn from_timeline(tl: &Timeline) -> PhaseBreakdown {
        PhaseBreakdown {
            rows: tl.records.iter().map(PhaseRow::from_record).collect(),
        }
    }

    /// The `repro trace` entry point: rebuild rows by pairing
    /// `open`/`close` span events (as parsed JSON objects, in file
    /// order). `broadcast`/`train`/`upload` children fold into their
    /// parent `round` span's row.
    pub fn from_events(events: &[Json]) -> PhaseBreakdown {
        let mut opens: BTreeMap<u64, &Json> = BTreeMap::new();
        // Child phase durations keyed by the enclosing round-span id.
        let mut pending: BTreeMap<u64, (Ticks, Ticks, Ticks)> = BTreeMap::new();
        let mut rows = Vec::new();
        for ev in events {
            let id = ev.get("id").and_then(Json::as_u64).unwrap_or(0);
            match ev.get("ev").and_then(Json::as_str) {
                Some("open") => {
                    opens.insert(id, ev);
                }
                Some("close") => {
                    let Some(open) = opens.remove(&id) else { continue };
                    let at_open = open.get("at").and_then(Json::as_u64).unwrap_or(0);
                    let at_close = ev.get("at").and_then(Json::as_u64).unwrap_or(at_open);
                    let dur = at_close.saturating_sub(at_open);
                    match open.get("name").and_then(Json::as_str) {
                        Some(phase @ ("broadcast" | "train" | "upload")) => {
                            if let Some(p) = open.get("parent").and_then(Json::as_u64) {
                                let e = pending.entry(p).or_insert((0, 0, 0));
                                match phase {
                                    "broadcast" => e.0 += dur,
                                    "train" => e.1 += dur,
                                    _ => e.2 += dur,
                                }
                            }
                        }
                        Some("round") => {
                            let (b, t, u) = pending.remove(&id).unwrap_or((0, 0, 0));
                            let f = |k: &str| {
                                open.path(&["f", k]).and_then(Json::as_usize).unwrap_or(0)
                            };
                            rows.push(PhaseRow {
                                round: f("round"),
                                start: at_open,
                                end: at_close,
                                broadcast: b,
                                train: t,
                                upload: u,
                                reporters: f("reporters"),
                            });
                        }
                        _ => {}
                    }
                }
                _ => {}
            }
        }
        PhaseBreakdown { rows }
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Per-round phase table (simulated seconds).
    pub fn table(&self) -> String {
        let mut out = format!(
            "{:>5} {:>9} {:>10} {:>9} {:>9} {:>9} {:>6}\n",
            "round", "start", "broadcast", "train", "upload", "total", "kept"
        );
        for r in &self.rows {
            out.push_str(&format!(
                "{:>5} {:>9} {:>10} {:>9} {:>9} {:>9} {:>6}\n",
                r.round,
                fmt_sim_secs(secs(r.start)),
                fmt_sim_secs(secs(r.broadcast)),
                fmt_sim_secs(secs(r.train)),
                fmt_sim_secs(secs(r.upload)),
                fmt_sim_secs(secs(r.total())),
                r.reporters,
            ));
        }
        out
    }

    /// Critical-path flame table: where the closing reporters' time went,
    /// summed across rounds.
    pub fn flame_table(&self) -> String {
        let b: Ticks = self.rows.iter().map(|r| r.broadcast).sum();
        let t: Ticks = self.rows.iter().map(|r| r.train).sum();
        let u: Ticks = self.rows.iter().map(|r| r.upload).sum();
        let total = (b + t + u).max(1);
        let n = self.rows.len().max(1);
        let mut out = format!(
            "{:<10} {:>9} {:>6} {:>10}\n",
            "phase", "total", "share", "mean/round"
        );
        for (name, ticks) in [("broadcast", b), ("train", t), ("upload", u)] {
            out.push_str(&format!(
                "{:<10} {:>9} {:>5.1}% {:>10}\n",
                name,
                fmt_sim_secs(secs(ticks)),
                100.0 * ticks as f64 / total as f64,
                fmt_sim_secs(secs(ticks / n as u64)),
            ));
        }
        out
    }

    /// One-line critical-path summary — the line `repro sim --quick`
    /// prints under each scheme, and `repro trace` prints per section.
    pub fn critical_path_line(&self) -> String {
        let b: Ticks = self.rows.iter().map(|r| r.broadcast).sum();
        let t: Ticks = self.rows.iter().map(|r| r.train).sum();
        let u: Ticks = self.rows.iter().map(|r| r.upload).sum();
        let total = (b + t + u).max(1) as f64;
        format!(
            "critical path: broadcast {:.0}% · train {:.0}% · upload {:.0}%",
            100.0 * b as f64 / total,
            100.0 * t as f64 / total,
            100.0 * u as f64 / total,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock::TimeSource;

    fn rec(round: usize, start: Ticks) -> TimelineRecord {
        TimelineRecord {
            round,
            start,
            end: start + 6_000_000,
            broadcast_ticks: 1_000_000,
            compute_ticks: 3_000_000,
            upload_ticks: 2_000_000,
            selected: 12,
            offline: 1,
            dropouts: 1,
            reporters: 10,
            stragglers_dropped: 0,
        }
    }

    #[test]
    fn timeline_and_trace_routes_agree() {
        let mut tl = Timeline::default();
        tl.push(rec(1, 0));
        tl.push(rec(2, 6_000_000));
        let direct = PhaseBreakdown::from_timeline(&tl);

        // Replay through the span model and rebuild from parsed events.
        let mut tracer = Tracer::new(TimeSource::manual(), 64);
        for r in &tl.records {
            emit_round_spans(&mut tracer, r);
        }
        let events: Vec<Json> = tracer
            .to_jsonl()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        let via_trace = PhaseBreakdown::from_events(&events);
        assert_eq!(direct, via_trace, "one phase model, two entry points");
        assert_eq!(via_trace.rows.len(), 2);
        assert_eq!(via_trace.rows[0].train, 3_000_000);
        assert_eq!(via_trace.rows[1].start, 6_000_000);
        assert_eq!(via_trace.rows[1].reporters, 10);
    }

    #[test]
    fn renderers_cover_the_rows() {
        let bd = PhaseBreakdown::from_timeline(&{
            let mut tl = Timeline::default();
            tl.push(rec(1, 0));
            tl
        });
        let table = bd.table();
        assert!(table.contains("round"));
        assert!(table.contains("3.0s"), "train phase rendered: {table}");
        let flame = bd.flame_table();
        assert!(flame.contains("50.0%"), "train share: {flame}");
        let line = bd.critical_path_line();
        assert!(line.contains("train 50%"), "{line}");
        assert!(PhaseBreakdown::default().is_empty());
    }
}
