//! Where instrumentation timestamps come from.
//!
//! The tracing plane never reads a clock directly: every event timestamp
//! flows through a [`TimeSource`], so instrumented code stays inside the
//! analyzer's determinism rules (`repro analyze` bans raw
//! `Instant`/`SystemTime`/`Stopwatch` reads across `fl/`, `sim/` and
//! `obs/` — this file's `wall` constructor is the one allowlisted
//! exception, in `rust/analyze.toml`).
//!
//! Three sources:
//!
//! * [`TimeSource::manual`] — externally driven virtual time: the runner
//!   copies the transport's sim clock into the tracer, so trace
//!   timestamps are integer sim ticks and byte-reproducible per seed.
//! * [`TimeSource::wall`] — monotonic wall clock anchored at creation,
//!   for runs without a virtual clock. Explicitly nondeterministic: the
//!   byte-identity contract (and its pinned test) excludes it.
//! * [`TimeSource::frozen`] — pinned at a fixed tick forever: unit tests
//!   that want stable timestamps without threading a clock.

use crate::sim::Ticks;

/// A timestamp source in integer microsecond ticks (the sim's unit).
#[derive(Debug, Clone)]
pub enum TimeSource {
    /// Virtual time, driven by the caller through [`TimeSource::set_now`].
    Manual { now: Ticks },
    /// Monotonic wall clock, anchored at construction.
    Wall { origin: std::time::Instant }, // analyze: allow(determinism): the wall-clock variant is the explicit nondeterministic escape hatch
    /// A constant instant (test fixtures).
    Frozen { at: Ticks },
}

impl TimeSource {
    /// Caller-driven virtual time starting at tick 0 (the sim path).
    pub fn manual() -> TimeSource {
        TimeSource::Manual { now: 0 }
    }

    /// Monotonic wall clock anchored now. Traces stamped from this source
    /// are NOT byte-reproducible across runs.
    pub fn wall() -> TimeSource {
        TimeSource::Wall {
            origin: std::time::Instant::now(),
        }
    }

    /// Pinned at `at` forever.
    pub fn frozen(at: Ticks) -> TimeSource {
        TimeSource::Frozen { at }
    }

    /// The current timestamp in ticks (µs).
    pub fn now(&self) -> Ticks {
        match self {
            TimeSource::Manual { now } => *now,
            TimeSource::Wall { origin } => origin.elapsed().as_micros() as Ticks,
            TimeSource::Frozen { at } => *at,
        }
    }

    /// Drive a `Manual` source to `t` (the caller owns monotonicity;
    /// replaying a completed timeline into spans may legitimately rewind).
    /// `Wall` and `Frozen` ignore it.
    pub fn set_now(&mut self, t: Ticks) {
        if let TimeSource::Manual { now } = self {
            *now = t;
        }
    }

    /// `true` when equal seeds replay byte-identical timestamps — the
    /// trace byte-identity contract holds for these sources only.
    pub fn is_deterministic(&self) -> bool {
        !matches!(self, TimeSource::Wall { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_is_caller_driven() {
        let mut c = TimeSource::manual();
        assert_eq!(c.now(), 0);
        c.set_now(42);
        assert_eq!(c.now(), 42);
        // Rewind is allowed (timeline replay).
        c.set_now(7);
        assert_eq!(c.now(), 7);
        assert!(c.is_deterministic());
    }

    #[test]
    fn frozen_ignores_the_driver() {
        let mut c = TimeSource::frozen(99);
        c.set_now(1);
        assert_eq!(c.now(), 99);
        assert!(c.is_deterministic());
    }

    #[test]
    fn wall_is_monotone_and_flagged_nondeterministic() {
        let mut c = TimeSource::wall();
        let a = c.now();
        c.set_now(0); // ignored
        let b = c.now();
        assert!(b >= a);
        assert!(!c.is_deterministic());
    }
}
