//! Zero-dependency observability plane: structured tracing + metrics for
//! the federation loop.
//!
//! Every window into a run flows through this module: the runner and the
//! dry-run transport loops emit span/point events into a bounded ring
//! ([`trace`]) and typed counters/gauges/histograms into a registry
//! ([`metrics`]); the sinks below turn those into artifacts.
//!
//! ```text
//!     fl::runner / fl::transport::dryrun          sim::Timeline
//!        │  live points: ingest verdicts,            │  completed records
//!        │  bit_plan, observe, downlink,             │  (round start/end +
//!        │  dispatch/arrive, eval, section           │  critical-path phases)
//!        ▼                                           ▼
//!   ┌──────────────────────────────┐    phases::emit_round_spans
//!   │ Tracer                       │◀── (timeline records replayed as
//!   │   TimeSource (clock)         │     round ▸ broadcast/train/upload
//!   │     manual │ wall │ frozen   │     span trees — satellite of the
//!   │   bounded event ring         │     one-code-path contract)
//!   │     overwrite-oldest,        │
//!   │     never reallocates        │   ┌──────────────────────────────┐
//!   └──────────────┬───────────────┘   │ Metrics                      │
//!                  │                   │   counters · gauges · hists  │
//!                  │  to_jsonl()       │   (BTreeMap ⇒ deterministic) │
//!                  ▼                   └───────┬──────────────┬───────┘
//!        one JSON object per line              │ to_json()    │ prometheus()
//!                  │            ┌──────────────┘              ▼
//!                  ▼            ▼                    text exposition
//!            render_trace: events + final
//!            {"metrics": …} snapshot line
//!                  │
//!                  ▼
//!            --trace FILE  ──────▶  repro trace FILE ([`explore`]):
//!            (byte-identical per     phase tables, flame table,
//!             seed under manual/     ingest verdict totals,
//!             frozen clocks —        allocator decision log,
//!             pinned by test)        metrics panel
//! ```
//!
//! Determinism contract: with a [`TimeSource::manual`] or
//! [`TimeSource::frozen`] clock, two runs at the same seed produce
//! byte-identical trace files — timestamps are integer sim ticks, event
//! ids are allocation-ordered, and both JSON emitters iterate `BTreeMap`s
//! (`tests/obs_trace.rs` pins the bytes). The wall clock is the one
//! explicitly nondeterministic escape hatch, allowlisted in
//! `rust/analyze.toml`; everything else in `obs/` passes the same
//! determinism rule that guards `fl/` and `sim/`.

pub mod clock;
pub mod explore;
pub mod metrics;
pub mod phases;
pub mod trace;

pub use clock::TimeSource;
pub use metrics::{Hist, Metrics};
pub use phases::{emit_round_spans, PhaseBreakdown, PhaseRow};
pub use trace::{Event, EventKind, SpanId, Tracer};

/// Default event-ring bound for `--trace` runs: big enough for every
/// event of a quick sim sweep, small enough (a few MiB) to sit in memory
/// for a million-device run — older events are overwritten past this.
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;

/// Serialize a completed run: the tracer's event ring as JSONL followed
/// by one `{"metrics": …}` snapshot line — the document `--trace FILE`
/// writes and [`explore::report`] reads.
pub fn render_trace(tracer: &Tracer, metrics: &Metrics) -> String {
    let mut out = tracer.to_jsonl();
    out.push_str(
        &crate::util::json::Json::obj()
            .set("metrics", metrics.to_json())
            .dump(),
    );
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_trace_ends_with_the_metrics_line() {
        let mut t = Tracer::new(TimeSource::frozen(1), 8);
        t.point("eval", Vec::new());
        let mut m = Metrics::new();
        m.inc("rounds", 2);
        let doc = render_trace(&t, &m);
        let lines: Vec<&str> = doc.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"eval\""));
        assert!(lines[1].starts_with("{\"metrics\":"));
        assert!(doc.ends_with('\n'));
    }
}
