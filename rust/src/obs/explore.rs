//! The `repro trace` explorer: load a `--trace` JSONL file and render
//! what the runner saw — per-round phase breakdowns (through the same
//! [`PhaseBreakdown`] code path `repro sim` uses), a critical-path flame
//! table, ingest verdict totals, the `BitController` decision log, and
//! the final metrics snapshot.
//!
//! Input format (what [`super::render_trace`] writes): one compact JSON
//! object per line — span/point events carry an `"ev"` key; the single
//! registry snapshot carries a `"metrics"` key.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

use super::phases::PhaseBreakdown;

/// Read `path` and render the full report.
pub fn explore_file(path: &Path) -> Result<String> {
    let doc = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace file {}", path.display()))?;
    report(&doc)
}

/// Render the report from an in-memory JSONL trace document.
pub fn report(doc: &str) -> Result<String> {
    let mut events: Vec<Json> = Vec::new();
    let mut metrics: Option<Json> = None;
    for (i, line) in doc.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow!("trace line {}: {e}", i + 1))?;
        if j.get("metrics").is_some() {
            metrics = Some(j);
        } else if j.get("ev").is_some() {
            events.push(j);
        } else {
            return Err(anyhow!("trace line {}: neither an event nor a metrics snapshot", i + 1));
        }
    }
    if events.is_empty() && metrics.is_none() {
        return Err(anyhow!("empty trace"));
    }

    let mut out = format!("trace: {} events\n", events.len());

    // -- sections (one per `section` point, e.g. per sim scheme) ----------
    for (label, block) in split_sections(&events) {
        let bd = PhaseBreakdown::from_events(block);
        if bd.is_empty() {
            continue;
        }
        out.push_str(&format!("\n== {label} ==\n"));
        out.push_str(&bd.table());
        out.push_str(&bd.critical_path_line());
        out.push('\n');
        out.push_str("\nflame (critical path):\n");
        out.push_str(&bd.flame_table());
    }

    // -- ingest verdict totals -------------------------------------------
    out.push_str(&verdict_totals(&events, metrics.as_ref()));

    // -- allocator decision log ------------------------------------------
    let decisions = decision_log(&events);
    if !decisions.is_empty() {
        out.push_str("\nallocator decisions:\n");
        out.push_str(&decisions);
    }

    // -- final metrics snapshot ------------------------------------------
    if let Some(m) = &metrics {
        out.push_str(&metrics_summary(m));
    }
    Ok(out)
}

/// Split the event stream into `(label, slice)` blocks at `section`
/// points. Events before the first section land in an `"all"` block.
fn split_sections(events: &[Json]) -> Vec<(String, &[Json])> {
    let mut cuts: Vec<(String, usize)> = Vec::new();
    for (i, ev) in events.iter().enumerate() {
        if ev.get("name").and_then(Json::as_str) == Some("section") {
            let label = ev
                .path(&["f", "label"])
                .and_then(Json::as_str)
                .unwrap_or("section")
                .to_string();
            cuts.push((label, i));
        }
    }
    if cuts.is_empty() {
        return vec![("all".to_string(), events)];
    }
    let mut blocks = Vec::new();
    if cuts[0].1 > 0 {
        blocks.push(("preamble".to_string(), &events[..cuts[0].1]));
    }
    for (j, (label, start)) in cuts.iter().enumerate() {
        let end = cuts.get(j + 1).map_or(events.len(), |c| c.1);
        blocks.push((label.clone(), &events[*start..end]));
    }
    blocks
}

/// Ingest verdict totals: prefer the metrics counters; fall back to
/// counting `ingest` points when the snapshot is absent.
fn verdict_totals(events: &[Json], metrics: Option<&Json>) -> String {
    let from_counters = |m: &Json, k: &str| {
        m.path(&["metrics", "counters", k]).and_then(Json::as_u64)
    };
    let (acc, dup, stale, mal) = match metrics {
        Some(m) if from_counters(m, "ingest_accepted").is_some() => (
            from_counters(m, "ingest_accepted").unwrap_or(0),
            from_counters(m, "ingest_duplicate").unwrap_or(0),
            from_counters(m, "ingest_stale").unwrap_or(0),
            from_counters(m, "ingest_malformed").unwrap_or(0),
        ),
        _ => {
            let mut t = (0u64, 0u64, 0u64, 0u64);
            for ev in events {
                if ev.get("name").and_then(Json::as_str) != Some("ingest") {
                    continue;
                }
                match ev.path(&["f", "verdict"]).and_then(Json::as_str) {
                    Some("accepted") => t.0 += 1,
                    Some("duplicate") => t.1 += 1,
                    Some("stale") => t.2 += 1,
                    Some("malformed") => t.3 += 1,
                    _ => {}
                }
            }
            t
        }
    };
    format!(
        "\ningest verdicts: accepted {acc} · duplicate {dup} · stale {stale} · malformed {mal}\n"
    )
}

/// The `BitController` decision log, one line per `bit_plan` point.
fn decision_log(events: &[Json]) -> String {
    let mut out = String::new();
    for ev in events {
        if ev.get("name").and_then(Json::as_str) != Some("bit_plan") {
            continue;
        }
        let f = |k: &str| ev.path(&["f", k]);
        let round = f("round").and_then(Json::as_usize).unwrap_or(0);
        let bits = f("bits").and_then(Json::as_str).unwrap_or("?").to_string();
        let segmented = f("segmented").map(|j| *j == Json::Bool(true)).unwrap_or(false);
        let cost = f("cost").and_then(Json::as_usize).unwrap_or(0);
        let budget = f("budget").and_then(Json::as_usize).unwrap_or(0);
        let floor = f("floor").and_then(Json::as_usize).unwrap_or(0);
        out.push_str(&format!(
            "  round {round:>3}: bits {bits}{} cost {cost}B budget {budget}B floor {floor}b\n",
            if segmented { " (segmented)" } else { " (uniform)" },
        ));
    }
    out
}

/// Counters + gauges from the final snapshot, one per line.
fn metrics_summary(m: &Json) -> String {
    let mut out = String::new();
    for (section, title) in [("counters", "counters"), ("gauges", "gauges")] {
        if let Some(obj) = m.path(&["metrics", section]).and_then(Json::as_obj) {
            if obj.is_empty() {
                continue;
            }
            out.push_str(&format!("\n{title}:\n"));
            for (k, v) in obj {
                out.push_str(&format!("  {k} = {}\n", v.dump()));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock::TimeSource;
    use crate::obs::phases::emit_round_spans;
    use crate::obs::trace::Tracer;
    use crate::obs::Metrics;
    use crate::sim::TimelineRecord;

    fn sample_doc() -> String {
        let mut t = Tracer::new(TimeSource::manual(), 256);
        t.point("section", vec![("label", Json::from("sync b4"))]);
        t.point(
            "bit_plan",
            vec![
                ("round", Json::from(1usize)),
                ("bits", Json::from("44")),
                ("segmented", Json::from(true)),
                ("cost", Json::from(152usize)),
                ("budget", Json::from(160usize)),
                ("floor", Json::from(1usize)),
            ],
        );
        for v in ["accepted", "accepted", "duplicate", "stale"] {
            t.point("ingest", vec![("verdict", Json::from(v))]);
        }
        emit_round_spans(
            &mut t,
            &TimelineRecord {
                round: 1,
                start: 0,
                end: 5_000_000,
                broadcast_ticks: 1_000_000,
                compute_ticks: 2_000_000,
                upload_ticks: 2_000_000,
                selected: 4,
                offline: 0,
                dropouts: 0,
                reporters: 2,
                stragglers_dropped: 0,
            },
        );
        let mut m = Metrics::new();
        m.inc("uplink_bytes", 304);
        m.set_gauge("residual_norm", 0.5);
        super::super::render_trace(&t, &m)
    }

    #[test]
    fn report_renders_all_panels() {
        let doc = sample_doc();
        let rep = report(&doc).expect("report");
        assert!(rep.contains("== sync b4 =="), "section header: {rep}");
        assert!(rep.contains("critical path:"), "{rep}");
        assert!(rep.contains("flame"), "{rep}");
        assert!(
            rep.contains("accepted 2 · duplicate 1 · stale 1 · malformed 0"),
            "verdict totals from ingest points: {rep}"
        );
        assert!(rep.contains("bits 44 (segmented)"), "decision log: {rep}");
        assert!(rep.contains("uplink_bytes = 304"), "metrics panel: {rep}");
    }

    #[test]
    fn counters_beat_point_counting_when_present() {
        let mut t = Tracer::new(TimeSource::frozen(0), 16);
        t.point("ingest", vec![("verdict", Json::from("accepted"))]);
        let mut m = Metrics::new();
        m.inc("ingest_accepted", 9);
        m.inc("ingest_malformed", 3);
        let rep = report(&super::super::render_trace(&t, &m)).unwrap();
        assert!(
            rep.contains("accepted 9 · duplicate 0 · stale 0 · malformed 3"),
            "{rep}"
        );
    }

    #[test]
    fn rejects_garbage_and_empty_docs() {
        assert!(report("").is_err());
        assert!(report("not json\n").is_err());
        assert!(report("{\"no_ev_key\":1}\n").is_err());
    }
}
