//! DEFLATE benchmarks on the payloads the system actually produces:
//! bit-packed quantized gradient codes (very compressible) and raw float32
//! bytes (barely compressible). Cross-referenced against flate2 (zlib) as
//! an external yardstick when built with `--features zlib-yardstick`
//! (flate2 is optional so offline builds need no extra crates).

use cossgd::compress::cosine::CosineQuantizer;
use cossgd::compress::deflate::{deflate, inflate, CompressionLevel};
use cossgd::compress::{bitpack, entropy};
use cossgd::util::bench::Bencher;
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::seeded(1);
    let n = 1 << 20;
    let g = gradient_like(&mut rng, n);
    let q = CosineQuantizer::paper_default(8).quantize(&g, &mut rng);
    let codes = bitpack::pack(&q.codes, 8);
    let floats = entropy::f32_bytes(&g);
    println!(
        "== deflate benchmarks: codes {} bytes, floats {} bytes ==",
        codes.len(),
        floats.len()
    );

    for level in [CompressionLevel::Fast, CompressionLevel::Default, CompressionLevel::Best] {
        let out = deflate(&codes, level);
        b.bench_bytes(
            &format!("deflate codes {level:?} (ratio {:.2}x)", codes.len() as f64 / out.len() as f64),
            codes.len() as u64,
            || deflate(&codes, level),
        );
    }
    let out = deflate(&floats, CompressionLevel::Default);
    b.bench_bytes(
        &format!(
            "deflate float32 Default (ratio {:.3}x)",
            floats.len() as f64 / out.len() as f64
        ),
        floats.len() as u64,
        || deflate(&floats, CompressionLevel::Default),
    );

    let compressed = deflate(&codes, CompressionLevel::Default);
    b.bench_bytes("inflate codes", codes.len() as u64, || {
        inflate(&compressed).unwrap()
    });

    // zlib yardstick (optional dependency).
    #[cfg(feature = "zlib-yardstick")]
    {
        use std::io::Write;
        b.bench_bytes("flate2(6) codes [yardstick]", codes.len() as u64, || {
            let mut e =
                flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::new(6));
            e.write_all(&codes).unwrap();
            e.finish().unwrap()
        });
    }
}
