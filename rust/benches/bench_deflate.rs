//! DEFLATE benchmarks on the payloads the system actually produces:
//! bit-packed quantized gradient codes (very compressible) and raw float32
//! bytes (barely compressible) — now including the **thread-scaling
//! series** for the parallel encoder (`deflate codes Default x4` etc.).
//! Before timing, every parallel case is asserted byte-identical to the
//! serial stream, so a speedup number can never come from divergent
//! output. Cross-referenced against flate2 (zlib) as an external
//! yardstick when built with `--features zlib-yardstick` (flate2 is
//! optional so offline builds need no extra crates).
//!
//! `--quick` caps sampling for CI smoke runs; `--json` **appends** a run
//! to `BENCH_compress.json` (suite `compress`, schema `cossgd-bench/v1`)
//! alongside the kernel series so DEFLATE MB/s accumulates in the same
//! committed trajectory.

use cossgd::compress::cosine::CosineQuantizer;
use cossgd::compress::deflate::{deflate, deflate_into, inflate, CompressionLevel};
use cossgd::compress::{bitpack, entropy, perf};
use cossgd::util::bench::{json_requested, quick_requested, write_trajectory, Bencher};
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

fn main() {
    let mut b = if quick_requested() {
        Bencher::quick()
    } else {
        Bencher::new()
    };
    let mut rng = Pcg64::seeded(1);
    let n = 1 << 20;
    let g = gradient_like(&mut rng, n);
    let q = CosineQuantizer::paper_default(8).quantize(&g, &mut rng);
    let codes = bitpack::pack(&q.codes, 8);
    let floats = entropy::f32_bytes(&g);
    println!(
        "== deflate benchmarks: codes {} bytes, floats {} bytes ==",
        codes.len(),
        floats.len()
    );

    // Thread-scaling series: level × threads, bit-identity asserted
    // against the serial stream before the clock starts.
    for level in [CompressionLevel::Fast, CompressionLevel::Default, CompressionLevel::Best] {
        let serial = deflate(&codes, level);
        for threads in [1usize, 4, 8] {
            let mut out = Vec::new();
            deflate_into(&codes, level, threads, &mut out);
            assert_eq!(out, serial, "parallel ({threads} threads) != serial at {level:?}");
            b.bench_bytes(
                &format!(
                    "deflate codes {level:?} x{threads} (ratio {:.2}x)",
                    codes.len() as f64 / serial.len() as f64
                ),
                codes.len() as u64,
                || {
                    let mut out = Vec::new();
                    deflate_into(&codes, level, threads, &mut out);
                    out
                },
            );
        }
    }
    let serial = deflate(&floats, CompressionLevel::Default);
    for threads in [1usize, 4, 8] {
        let mut out = Vec::new();
        deflate_into(&floats, CompressionLevel::Default, threads, &mut out);
        assert_eq!(out, serial, "parallel float32 ({threads} threads) != serial");
        b.bench_bytes(
            &format!(
                "deflate float32 Default x{threads} (ratio {:.3}x)",
                floats.len() as f64 / serial.len() as f64
            ),
            floats.len() as u64,
            || {
                let mut out = Vec::new();
                deflate_into(&floats, CompressionLevel::Default, threads, &mut out);
                out
            },
        );
    }

    let compressed = deflate(&codes, CompressionLevel::Default);
    b.bench_bytes("inflate codes", codes.len() as u64, || {
        inflate(&compressed).unwrap()
    });

    // zlib yardstick (optional dependency).
    #[cfg(feature = "zlib-yardstick")]
    {
        use std::io::Write;
        b.bench_bytes("flate2(6) codes [yardstick]", codes.len() as u64, || {
            let mut e =
                flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::new(6));
            e.write_all(&codes).unwrap();
            e.finish().unwrap()
        });
    }

    if json_requested() {
        let path = std::path::Path::new("BENCH_compress.json");
        write_trajectory(path, perf::SUITE, b.results()).expect("write trajectory");
        println!("run appended to {path:?}");
    }
}
