//! Sharded ingest-plane throughput: how fast prepared CSG2 frames fold
//! into the server accumulator at 1/4/16 shards, for both frame shapes
//! (whole-tensor single-segment "legacy" frames and segmented
//! mixed-width streams) and both flush cadences (batched = one flush
//! per sync round, streamed = one flush per arrival, the buffered-async
//! worst case). `elems_per_iter` counts accumulator elements folded, so
//! ns/elem in the trajectory is directly comparable across shapes;
//! frames/sec headlines are printed per case.
//!
//! The merge contract is asserted inline before timing: every shard
//! count must produce a bit-identical accumulator — the parallel plane
//! is an optimization, never a different answer.
//!
//! Every run **appends** to `BENCH_ingest.json` (same `cossgd-bench/v1`
//! schema as `BENCH_compress.json` / `BENCH_sim.json`) so the committed
//! trajectory accumulates a point per CI run instead of sitting empty.
//! `--quick` caps sampling for CI smoke runs.

use cossgd::compress::{Direction, EncodeScratch, LayerMap, Pipeline, PipelineState};
use cossgd::fl::{IngestPlane, PreparedFrame, PreparedSegment};
use cossgd::util::bench::{quick_requested, write_trajectory, Bencher};
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

/// Accumulator extent per frame (64k params — big enough that the fold
/// dominates thread-spawn overhead, small enough for quick CI runs).
const N: usize = 1 << 16;
/// Layers in the segmented shape (widths cycle 1..=8 across them).
const LAYERS: usize = 32;
/// Frames per batched flush (one sync round's worth of arrivals).
const FRAMES: usize = 16;

/// Encode one synthetic update as a prepared frame. `segmented` encodes
/// per-layer mixed-width segments; otherwise one whole-tensor segment.
/// Deflate stays off: inflation happens once on the coordinator at
/// prepare time, and this bench times the fold, not the inflate.
fn prepared_frame(map: &LayerMap, segmented: bool, salt: u64) -> PreparedFrame {
    let mut rng = Pcg64::new(salt, 0xF01D);
    let g = gradient_like(&mut rng, map.param_count());
    let mut scratch = EncodeScratch::new();
    let mut segments = Vec::new();
    if segmented {
        for l in 0..map.len() {
            let seg = map.segment(l);
            let bits = 1 + ((salt as usize + l) % 8) as u8;
            let pipe = Pipeline::cosine(bits).without_deflate();
            let enc = pipe.encode(
                &g[seg.clone()],
                Direction::Uplink,
                &mut PipelineState::new(),
                &mut rng,
            );
            segments.push(
                PreparedSegment::prepare(enc, seg.start, &mut scratch).expect("prepare segment"),
            );
        }
    } else {
        let pipe = Pipeline::cosine(4).without_deflate();
        let enc = pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
        segments.push(PreparedSegment::prepare(enc, 0, &mut scratch).expect("prepare frame"));
    }
    PreparedFrame::new(1.0 / FRAMES as f64, segments)
}

/// Fold `frames` through a fresh plane at `shards` and return the bits.
fn fold_bits(map: &LayerMap, frames: &[PreparedFrame], shards: usize) -> Vec<u64> {
    let mut plane = IngestPlane::new(shards, map).with_capacity(FRAMES);
    let mut acc = vec![0.0f64; map.param_count()];
    for f in frames {
        plane.submit(f.clone());
    }
    plane.flush(&mut acc).expect("flush");
    acc.iter().map(|v| v.to_bits()).collect()
}

fn main() {
    let mut b = if quick_requested() {
        Bencher::quick()
    } else {
        Bencher::new()
    };
    let map = LayerMap::even(N, LAYERS);

    for (shape, segmented) in [("segmented", true), ("single-frame", false)] {
        let frames: Vec<PreparedFrame> = (0..FRAMES)
            .map(|f| prepared_frame(&map, segmented, f as u64))
            .collect();

        // The determinism contract, asserted before any timing: every
        // shard count folds to the bit-identical accumulator.
        let serial = fold_bits(&map, &frames, 1);
        for shards in [4usize, 16] {
            assert_eq!(
                fold_bits(&map, &frames, shards),
                serial,
                "{shape}: {shards}-shard fold diverged from serial"
            );
        }

        for shards in [1usize, 4, 16] {
            let mut plane = IngestPlane::new(shards, &map).with_capacity(FRAMES);
            let mut acc = vec![0.0f64; N];

            // Batched cadence: a sync round's arrivals, one flush.
            let case = format!("ingest {shape} shards={shards} batched");
            b.bench_elems(&case, (FRAMES * N) as u64, || {
                for f in &frames {
                    plane.submit(f.clone());
                }
                plane.flush(&mut acc).expect("flush");
                acc[0]
            });
            report_frames_per_sec(&b, FRAMES as f64);

            // Streamed cadence: buffered-async worst case, one flush per
            // arrival — granularity never changes bits, only throughput.
            let case = format!("ingest {shape} shards={shards} streamed");
            b.bench_elems(&case, (FRAMES * N) as u64, || {
                for f in &frames {
                    plane.submit(f.clone());
                    plane.flush(&mut acc).expect("flush");
                }
                acc[0]
            });
            report_frames_per_sec(&b, FRAMES as f64);
        }
    }

    println!("{} cases done", b.results().len());
    let path = std::path::Path::new("BENCH_ingest.json");
    write_trajectory(path, "ingest", b.results()).expect("write trajectory");
    println!("run appended to {path:?} (elems = accumulator elements folded per iteration)");
}

/// Print the last case's throughput as frames/sec (the headline the
/// acceptance gate reads: ≥2x at 4 shards vs serial on segmented
/// mixed-width frames).
fn report_frames_per_sec(b: &Bencher, frames_per_iter: f64) {
    if let Some(r) = b.results().last() {
        let secs = r.mean.as_secs_f64().max(1e-12);
        println!("    └ {:>10.0} frames/sec", frames_per_iter / secs);
    }
}
