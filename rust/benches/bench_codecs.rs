//! Compression micro-benchmarks: the per-layer quantize/dequantize hot
//! path (millions of elements per client round). Drives EXPERIMENTS.md
//! §Perf L3.

use cossgd::compress::cosine::{BoundMode, CosineQuantizer, Rounding};
use cossgd::compress::linear::LinearQuantizer;
use cossgd::compress::{
    bitpack, decode, hadamard, signsgd, sparsify, Direction, Pipeline, PipelineState,
};
use cossgd::util::bench::Bencher;
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Pcg64::seeded(1);
    let n = 1 << 20; // ~1M elements ≈ the MNIST CNN layer scale
    let g = gradient_like(&mut rng, n);
    println!("== compression benchmarks (n = {n}) ==");

    for bits in [2u8, 8] {
        let q = CosineQuantizer::new(bits, Rounding::Biased, BoundMode::ClipTopPercent(1.0));
        b.bench_elems(&format!("cosine quantize biased {bits}b"), n as u64, || {
            q.quantize(&g, &mut Pcg64::seeded(2))
        });
        let qu = CosineQuantizer::new(bits, Rounding::Unbiased, BoundMode::Auto);
        b.bench_elems(&format!("cosine quantize unbiased {bits}b"), n as u64, || {
            qu.quantize(&g, &mut Pcg64::seeded(2))
        });
        let quantized = q.quantize(&g, &mut rng);
        b.bench_elems(&format!("cosine dequantize {bits}b"), n as u64, || {
            quantized.dequantize()
        });
        let lin = LinearQuantizer::unbiased(bits);
        b.bench_elems(&format!("linear quantize unbiased {bits}b"), n as u64, || {
            lin.quantize(&g, &mut Pcg64::seeded(2))
        });
        let codes = quantized.codes.clone();
        b.bench_elems(&format!("bitpack {bits}b"), n as u64, || {
            bitpack::pack(&codes, bits)
        });
        let packed = bitpack::pack(&codes, bits);
        b.bench_elems(&format!("bitunpack {bits}b"), n as u64, || {
            bitpack::unpack(&packed, bits, n)
        });
    }

    b.bench_elems("fwht rotate (pow2 pad)", n as u64, || hadamard::rotate(&g, 7));
    let rot = hadamard::rotate(&g, 7);
    b.bench_elems("fwht unrotate", n as u64, || {
        hadamard::unrotate(&rot, 7, n)
    });

    b.bench_elems("sign codes", n as u64, || signsgd::sign_codes(&g));
    b.bench_elems("sparsify mask 5%", n as u64, || sparsify::mask(9, n, 0.05));
    let m = sparsify::mask(9, n, 0.05);
    b.bench_elems("gather 5%", m.kept.len() as u64, || sparsify::gather(&g, &m));

    // Whole-pipeline encode/decode (what a client round pays).
    for pipe in [
        Pipeline::cosine(2),
        Pipeline::cosine(2).with_sparsify(0.05),
        Pipeline::cosine(8),
    ] {
        let label = format!("pipeline encode {}", pipe.name());
        b.bench_elems(&label, n as u64, || {
            pipe.encode(
                &g,
                Direction::Uplink,
                &mut PipelineState::new(),
                &mut Pcg64::seeded(3),
            )
        });
        let enc = pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
        let label = format!("pipeline decode {}", pipe.name());
        b.bench_elems(&label, n as u64, || decode(&enc).unwrap());
    }
}
