//! Systems-simulator overhead: pure event-engine throughput, no
//! artifacts and no training. The simulator must stay a rounding error
//! next to real local training — these numbers bound what it costs per
//! round at various fleet scales (3 events per participant: broadcast →
//! train → upload).
//!
//! Every case annotates its event count, so ns/elem in the trajectory IS
//! ns/event; `--json` **appends** a run to `BENCH_sim.json` in the same
//! `cossgd-bench/v1` schema as `BENCH_compress.json` — sim and compress
//! perf share one accumulating trajectory file format across PRs.
//! `--quick` caps sampling for CI smoke runs.

use cossgd::obs::Tracer;
use cossgd::sim::{ClientLoad, FleetSim, RoundPlan, RoundPolicy, SimConfig};
use cossgd::util::bench::{json_requested, quick_requested, write_trajectory, Bencher};
use cossgd::util::json::Json;

fn loads_for(plan: &RoundPlan, upload_bytes: usize) -> Vec<ClientLoad> {
    plan.active
        .iter()
        .map(|&device| ClientLoad {
            device,
            // Vary sizes so the event heap sees distinct finish times.
            upload_bytes: upload_bytes + device % 997,
            examples: 600,
        })
        .collect()
}

fn main() {
    let mut b = if quick_requested() {
        Bencher::quick()
    } else {
        Bencher::new()
    };
    println!("== fleet sampling ==");
    for &n in &[1_000usize, 100_000, 1_000_000] {
        let cfg = SimConfig::heterogeneous();
        b.bench_elems(&format!("sample fleet n={n}"), n as u64, || {
            FleetSim::new(&cfg, n, 7)
        });
    }

    println!("== round replay (sync policy) ==");
    for &(n, k) in &[(1_000usize, 100usize), (100_000, 1_000), (1_000_000, 10_000)] {
        let cfg = SimConfig::heterogeneous();
        let mut sim = FleetSim::new(&cfg, n, 7);
        let candidates: Vec<usize> = (0..k).collect();
        let mut round = 0usize;
        b.bench_elems(
            &format!("sim round n={n} k={k} sync"),
            (k * 3) as u64,
            || {
                round += 1;
                let plan = sim.begin_round(&candidates);
                let loads = loads_for(&plan, 50_000);
                sim.complete_round(round, &plan, k, 400_000, &loads)
            },
        );
    }

    println!("== round replay (deadline over-selection x1.3) ==");
    let cfg = SimConfig::heterogeneous()
        .with_policy(RoundPolicy::OverSelect { over_sample: 1.3 });
    let mut sim = FleetSim::new(&cfg, 100_000, 7);
    let k = 1_000usize;
    let candidates: Vec<usize> = (0..sim.selection_count(k)).collect();
    let mut round = 0usize;
    b.bench_elems(
        &format!("sim round n=100000 k={k} overselect"),
        (candidates.len() * 3) as u64,
        || {
            round += 1;
            let plan = sim.begin_round(&candidates);
            let loads = loads_for(&plan, 50_000);
            sim.complete_round(round, &plan, k, 400_000, &loads)
        },
    );

    println!("== tracing-off overhead guard ==");
    // The tracing-disabled fast path must stay event-free AND
    // allocation-free: a run without `--trace` pays a branch per probe,
    // nothing more. Measured here so a regression shows up as a perf
    // trajectory jump, asserted so it fails loudly.
    let mut tracer = Tracer::disabled();
    let probes = 1_000_000u64;
    b.bench_elems("tracer disabled probe", probes, || {
        for i in 0..probes {
            let span = tracer.open("round");
            tracer.point("ingest", vec![("i", Json::from(i))]);
            tracer.close(span);
        }
        tracer.len()
    });
    assert_eq!(tracer.len(), 0, "disabled tracer recorded events");
    assert_eq!(tracer.dropped(), 0, "disabled tracer counted drops");
    assert_eq!(tracer.allocated_capacity(), 0, "disabled tracer allocated a ring");

    let total_cases = b.results().len();
    println!("{total_cases} cases done");
    if json_requested() {
        let path = std::path::Path::new("BENCH_sim.json");
        write_trajectory(path, "sim", b.results()).expect("write trajectory");
        println!("run appended to {path:?} (ns_per_elem = ns per simulator event)");
    }
}
