//! PJRT runtime benchmarks: artifact execute latency for each model's
//! round/eval executables and the Pallas kernel path. Requires
//! `make artifacts`; prints a skip message otherwise.

use cossgd::data::partition::eval_set;
use cossgd::data::synth::{SynthCifar, SynthMnist, SynthTask};
use cossgd::runtime::manifest::init_params;
use cossgd::runtime::Engine;
use cossgd::util::bench::Bencher;
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_runtime: artifacts not built (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(dir).expect("engine");
    let mut b = Bencher::new();
    let mut rng = Pcg64::seeded(1);
    println!("== runtime benchmarks (PJRT CPU) ==");

    // MNIST local round (60 steps of B=10 over the 1.66M-param CNN).
    {
        let model = engine.manifest.model("mnist").unwrap().clone();
        let cfg = engine.manifest.round("mnist").unwrap();
        let params = init_params(&model, 1);
        let task = SynthMnist::new(1);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..cfg.n_data {
            let (xi, yi) = task.gen(i % 10, (i / 10) as u64);
            x.extend_from_slice(&xi);
            y.push(yi[0]);
        }
        let perms: Vec<i32> = (0..cfg.epochs)
            .flat_map(|_| rng.permutation(cfg.n_data))
            .map(|i| i as i32)
            .collect();
        engine.warmup(&["mnist_round"]).unwrap();
        b.bench("mnist_round (60 steps, B=10)", || {
            engine
                .local_round("mnist_round", &params, x.clone(), y.clone(), perms.clone(), 0.1)
                .unwrap()
        });

        let n = cfg.eval_n;
        let (ex, ey) = eval_set(&task, n);
        engine.warmup(&["mnist_eval"]).unwrap();
        b.bench("mnist_eval (1000 examples)", || {
            engine
                .classification_eval("mnist_eval", &params, ex.clone(), ey.clone(), n)
                .unwrap()
        });
    }

    // CIFAR local round.
    {
        let model = engine.manifest.model("cifar").unwrap().clone();
        let cfg = engine.manifest.round("cifar_e1").unwrap();
        let params = init_params(&model, 1);
        let task = SynthCifar::new(1);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..cfg.n_data {
            let (xi, yi) = task.gen(i % 10, (i / 10) as u64);
            x.extend_from_slice(&xi);
            y.push(yi[0]);
        }
        let perms: Vec<i32> = (0..cfg.epochs)
            .flat_map(|_| rng.permutation(cfg.n_data))
            .map(|i| i as i32)
            .collect();
        engine.warmup(&["cifar_round_e1"]).unwrap();
        // E=1 artifact: the E=5 round costs ~3 min/iter on one core (that
        // number is recorded once in EXPERIMENTS.md section Perf).
        b.bench("cifar_round_e1 (10 steps, B=50)", || {
            engine
                .local_round("cifar_round_e1", &params, x.clone(), y.clone(), perms.clone(), 0.05)
                .unwrap()
        });
    }

    // Pallas kernel chunk (65536 elements) vs native Rust quantizer.
    {
        let chunk = engine.manifest.chunk;
        let g = gradient_like(&mut rng, chunk);
        let norm = cossgd::util::stats::l2_norm(&g) as f32;
        let u = vec![0.5f32; chunk];
        engine.warmup(&["quant_cos_8", "dequant_cos_8"]).unwrap();
        b.bench_elems("pallas quant_cos_8 (1 chunk)", chunk as u64, || {
            engine.kernel_quantize(8, &g, norm, 0.5, &u).unwrap()
        });
        let codes = engine.kernel_quantize(8, &g, norm, 0.5, &u).unwrap();
        b.bench_elems("pallas dequant_cos_8 (1 chunk)", chunk as u64, || {
            engine.kernel_dequantize(8, &codes, norm, 0.5).unwrap()
        });
        use cossgd::compress::cosine::{BoundMode, CosineQuantizer, Rounding};
        let q = CosineQuantizer::new(8, Rounding::Biased, BoundMode::FixedAngle(0.5));
        b.bench_elems("native quantize (same chunk)", chunk as u64, || {
            q.quantize(&g, &mut Pcg64::seeded(2))
        });
    }
}
