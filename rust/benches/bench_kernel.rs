//! Kernel micro-benchmarks: the transcendental-free quantize (threshold
//! search) vs the reference `acos` path, LUT dequantize, and the
//! word-at-a-time bit packer — the compress perf trajectory.
//!
//! `--quick` caps sampling for CI smoke runs; `--json` **appends** a run
//! to `BENCH_compress.json` (schema `cossgd-bench/v1`) so ns/elem numbers
//! accumulate and stay comparable across PRs.

use cossgd::compress::perf;
use cossgd::util::bench::{json_requested, quick_requested, write_trajectory, Bencher};

fn main() {
    let mut b = if quick_requested() {
        Bencher::quick()
    } else {
        Bencher::new()
    };
    let n = 1 << 20; // ~1M elements, the scale of the acceptance criterion
    perf::run_suite(&mut b, n, 1);
    if let Some(speedup) = perf::headline_speedup(b.results()) {
        println!("headline: 4-bit biased quantize+pack kernel speedup {speedup:.1}x vs reference");
    }
    if json_requested() {
        let path = std::path::Path::new("BENCH_compress.json");
        write_trajectory(path, perf::SUITE, b.results()).expect("write trajectory");
        println!("run appended to {path:?}");
    }
}
