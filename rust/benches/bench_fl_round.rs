//! End-to-end federated-round benchmarks — one per paper table's workload:
//! a full FedAvg round (client local training through PJRT + encode +
//! wire + server decode/aggregate) for each (model, codec) cell. This is
//! the number the paper's "communication rounds" cost out to wall-clock.

use cossgd::compress::Codec;
use cossgd::fl::{self, FlConfig};
use cossgd::runtime::Engine;
use cossgd::util::bench::Bencher;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_fl_round: artifacts not built (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(dir).expect("engine");
    let mut b = Bencher::new();
    // Long-running cases: cap iterations via a short min_time override is
    // handled by BENCH_MIN_TIME_MS; each case below runs ≥1 full round.
    println!("== end-to-end FL round benchmarks ==");

    let cases: Vec<(&str, FlConfig)> = vec![
        (
            "mnist round float32 (Figs 6)",
            FlConfig::mnist(false).with_rounds(1).with_codec(Codec::float32()),
        ),
        (
            "mnist round cosine-2 (Figs 6/8)",
            FlConfig::mnist(false).with_rounds(1).with_codec(Codec::cosine(2)),
        ),
        (
            "cifar(E=1) round cosine-2@5% (Fig 10/Tab 1-2)",
            // E=1 artifact: the E=5 round costs ~3min/client on one core.
            FlConfig::cifar_e1()
                .with_rounds(1)
                .with_codec(Codec::cosine(2).with_sparsify(0.05)),
        ),
        (
            "unet round cosine-8 (Fig 9)",
            FlConfig::unet().with_rounds(1).with_codec(Codec::cosine(8)),
        ),
    ];
    for (label, mut cfg) in cases {
        cfg.eval_every = 0;
        cfg.n_clients = cfg.n_clients.min(20);
        b.bench(label, || fl::run(&cfg, &engine).unwrap());
    }
}
