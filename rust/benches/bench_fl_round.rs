//! End-to-end federated-round benchmarks — one per paper table's workload:
//! a full FedAvg round (client local training through PJRT + encode +
//! wire + server decode/aggregate) for each (model, codec) cell, plus the
//! downlink (model-delta) encode/decode path so round-trip overhead shows
//! up in the perf trajectory. This is the number the paper's
//! "communication rounds" cost out to wall-clock.

use cossgd::compress::{decode, wire, Direction, Pipeline, PipelineState};
use cossgd::fl::{self, FlConfig};
use cossgd::runtime::Engine;
use cossgd::util::bench::Bencher;
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

/// Downlink model-delta encode/decode (no artifacts needed): what the
/// server pays per broadcast and a client per received frame.
fn bench_downlink(b: &mut Bencher) {
    println!("== downlink (model delta) encode/decode benchmarks ==");
    let n = 1 << 20; // ~1M params ≈ the MNIST CNN
    let mut rng = Pcg64::seeded(1);
    let delta = gradient_like(&mut rng, n);
    for pipe in [Pipeline::cosine(8), Pipeline::cosine(4)] {
        let label = format!("downlink encode Δ {}", pipe.name());
        b.bench_elems(&label, n as u64, || {
            pipe.encode(
                &delta,
                Direction::Downlink,
                &mut PipelineState::new(),
                &mut Pcg64::seeded(2),
            )
        });
        let enc = pipe.encode(
            &delta,
            Direction::Downlink,
            &mut PipelineState::new(),
            &mut rng,
        );
        let frame = wire::serialize(&enc);
        let label = format!(
            "downlink decode Δ {} ({} bytes/client)",
            pipe.name(),
            frame.len()
        );
        b.bench_elems(&label, n as u64, || {
            decode(&wire::deserialize(&frame).unwrap()).unwrap()
        });
    }
}

fn main() {
    let mut b = Bencher::new();
    bench_downlink(&mut b);

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_fl_round FL rounds: artifacts not built (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(dir).expect("engine");
    // Long-running cases: cap iterations via a short min_time override is
    // handled by BENCH_MIN_TIME_MS; each case below runs ≥1 full round.
    println!("== end-to-end FL round benchmarks ==");

    let cases: Vec<(&str, FlConfig)> = vec![
        (
            "mnist round float32 (Figs 6)",
            FlConfig::mnist(false).with_rounds(1).with_uplink(Pipeline::float32()),
        ),
        (
            "mnist round cosine-2 (Figs 6/8)",
            FlConfig::mnist(false).with_rounds(1).with_uplink(Pipeline::cosine(2)),
        ),
        (
            "mnist round-trip cosine-4↑/cosine-8↓",
            FlConfig::mnist(false)
                .with_rounds(1)
                .with_uplink(Pipeline::cosine(4))
                .with_downlink(Pipeline::cosine(8)),
        ),
        (
            "cifar(E=1) round cosine-2@5% (Fig 10/Tab 1-2)",
            // E=1 artifact: the E=5 round costs ~3min/client on one core.
            FlConfig::cifar_e1()
                .with_rounds(1)
                .with_uplink(Pipeline::cosine(2).with_sparsify(0.05)),
        ),
        (
            "unet round cosine-8 (Fig 9)",
            FlConfig::unet().with_rounds(1).with_uplink(Pipeline::cosine(8)),
        ),
    ];
    for (label, mut cfg) in cases {
        cfg.eval_every = 0;
        cfg.n_clients = cfg.n_clients.min(20);
        b.bench(label, || fl::run(&cfg, &engine).unwrap());
    }
}
